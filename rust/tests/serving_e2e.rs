//! Integration: the live threaded serving system (queue + monitor +
//! Elastico + executor) under a spike, with a scripted engine — asserts
//! the paper's qualitative Fig. 5 result without needing artifacts.

use compass::metrics::RunSummary;
use compass::planner::{derive_plan, AqmParams, LatencyProfile, ProfiledConfig};
use compass::serving::executor::MockEngine;
use compass::serving::{serve, ElasticoPolicy, ServeOptions, StaticPolicy};
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

fn front() -> Vec<ProfiledConfig> {
    let mk = |label: &str, acc: f64, mean: f64| ProfiledConfig {
        config: vec![],
        label: label.into(),
        accuracy: acc,
        latency: LatencyProfile {
            mean_ms: mean,
            p50_ms: mean,
            p95_ms: mean * 1.2,
            runs: 10,
        },
    };
    vec![mk("fast", 0.76, 4.0), mk("medium", 0.82, 10.0), mk("accurate", 0.85, 24.0)]
}

fn run(policy_idx: Option<usize>, arrivals: &[f64], slo: f64) -> RunSummary {
    let plan = derive_plan(&front(), AqmParams::for_slo(slo));
    // Scale the hysteresis to the compressed timescale of this test.
    let mut plan = plan;
    plan.down_cooldown_ms = 500.0;
    let policy: Box<dyn compass::serving::ScalingPolicy> = match policy_idx {
        None => Box::new(ElasticoPolicy::new(plan.clone())),
        Some(i) => Box::new(StaticPolicy::new(i, "static")),
    };
    let out = serve(
        || {
            Ok(MockEngine {
                service_ms: vec![4.0, 10.0, 24.0],
                accuracy: vec![0.76, 0.82, 0.85],
            })
        },
        policy,
        arrivals,
        &ServeOptions {
            queue_capacity: 8192,
            tick_ms: 5,
            workers: 1,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    RunSummary::compute(&out.records, &out.switches, slo, 3)
}

#[test]
fn elastico_beats_statics_under_live_spike() {
    // Base ~27 qps (util 0.65 of accurate), 4x spike in the middle third
    // of a 12s run; SLO = 2.2x accurate mean.
    let arrivals = generate_arrivals(&WorkloadSpec {
        base_qps: 27.0,
        duration_s: 12.0,
        pattern: Pattern::paper_spike(),
        seed: 3,
    });
    let slo = 2.2 * 24.0;

    let ela = run(None, &arrivals, slo);
    let fast = run(Some(0), &arrivals, slo);
    let acc = run(Some(2), &arrivals, slo);

    assert!(
        ela.slo_compliance > acc.slo_compliance + 0.15,
        "elastico {:.2} vs accurate {:.2}",
        ela.slo_compliance,
        acc.slo_compliance
    );
    assert!(
        ela.mean_accuracy > fast.mean_accuracy + 0.005,
        "elastico {:.3} vs fast {:.3}",
        ela.mean_accuracy,
        fast.mean_accuracy
    );
    assert!(ela.switches >= 2, "no adaptation happened");
    assert!(ela.slo_compliance > 0.85, "elastico compliance {}", ela.slo_compliance);
}

#[test]
fn all_requests_accounted_for() {
    let arrivals = generate_arrivals(&WorkloadSpec {
        base_qps: 40.0,
        duration_s: 3.0,
        pattern: Pattern::Steady,
        seed: 5,
    });
    let s = run(None, &arrivals, 100.0);
    assert_eq!(s.requests, arrivals.len());
}
