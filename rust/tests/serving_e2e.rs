//! Integration: the live threaded serving system (queue + monitor +
//! Elastico + executor) under a spike, with a scripted engine — asserts
//! the paper's qualitative Fig. 5 result without needing artifacts.

use compass::metrics::RunSummary;
use compass::planner::{derive_plan, AqmParams, LatencyProfile, ProfiledConfig};
use compass::serving::executor::MockEngine;
use compass::serving::{serve, ElasticoPolicy, ServeOptions, StaticPolicy};
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

fn front() -> Vec<ProfiledConfig> {
    let mk = |label: &str, acc: f64, mean: f64| ProfiledConfig {
        config: vec![],
        label: label.into(),
        accuracy: acc,
        latency: LatencyProfile {
            mean_ms: mean,
            p50_ms: mean,
            p95_ms: mean * 1.2,
            runs: 10,
        },
    };
    vec![mk("fast", 0.76, 4.0), mk("medium", 0.82, 10.0), mk("accurate", 0.85, 24.0)]
}

fn run(policy_idx: Option<usize>, arrivals: &[f64], slo: f64) -> RunSummary {
    run_batched(policy_idx, arrivals, slo, 1, 0.0)
}

/// [`run`] with an executor batch bound and a per-dispatch fixed cost α
/// (part of each rung's 4/10/24 ms single-request service time).
fn run_batched(
    policy_idx: Option<usize>,
    arrivals: &[f64],
    slo: f64,
    batch: usize,
    dispatch_ms: f64,
) -> RunSummary {
    let plan = derive_plan(
        &front(),
        AqmParams::for_slo(slo).with_batch(batch, dispatch_ms),
    );
    // Scale the hysteresis to the compressed timescale of this test.
    let mut plan = plan;
    plan.down_cooldown_ms = 500.0;
    let policy: Box<dyn compass::serving::ScalingPolicy> = match policy_idx {
        None => Box::new(ElasticoPolicy::new(plan.clone())),
        Some(i) => Box::new(StaticPolicy::new(i, "static")),
    };
    let n_arrivals = arrivals.len();
    let out = serve(
        move || {
            Ok(MockEngine {
                service_ms: vec![4.0, 10.0, 24.0],
                accuracy: vec![0.76, 0.82, 0.85],
                dispatch_ms,
            })
        },
        policy,
        arrivals,
        &ServeOptions {
            queue_capacity: 8192,
            tick_ms: 5,
            workers: 1,
            batch,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    // Injector conservation: nothing may vanish between the arrival
    // trace and the outcome, whatever the batch bound.
    assert_eq!(
        out.records.len() + out.rejected,
        n_arrivals,
        "records + rejected != arrivals"
    );
    RunSummary::compute(&out.records, &out.switches, slo, 3)
}

#[test]
fn elastico_beats_statics_under_live_spike() {
    // Base ~27 qps (util 0.65 of accurate), 4x spike in the middle third
    // of a 12s run; SLO = 2.2x accurate mean.
    let arrivals = generate_arrivals(&WorkloadSpec {
        base_qps: 27.0,
        duration_s: 12.0,
        pattern: Pattern::paper_spike(),
        seed: 3,
    });
    let slo = 2.2 * 24.0;

    let ela = run(None, &arrivals, slo);
    let fast = run(Some(0), &arrivals, slo);
    let acc = run(Some(2), &arrivals, slo);

    assert!(
        ela.slo_compliance > acc.slo_compliance + 0.15,
        "elastico {:.2} vs accurate {:.2}",
        ela.slo_compliance,
        acc.slo_compliance
    );
    assert!(
        ela.mean_accuracy > fast.mean_accuracy + 0.005,
        "elastico {:.3} vs fast {:.3}",
        ela.mean_accuracy,
        fast.mean_accuracy
    );
    assert!(ela.switches >= 2, "no adaptation happened");
    assert!(ela.slo_compliance > 0.85, "elastico compliance {}", ela.slo_compliance);
}

#[test]
fn all_requests_accounted_for() {
    let arrivals = generate_arrivals(&WorkloadSpec {
        base_qps: 40.0,
        duration_s: 3.0,
        pattern: Pattern::Steady,
        seed: 5,
    });
    let s = run(None, &arrivals, 100.0);
    assert_eq!(s.requests, arrivals.len());
}

#[test]
fn batched_serving_accounts_for_everything_and_stays_compliant() {
    // The live stack end-to-end at B = 8 with a dominant dispatch cost
    // (α = 3 of the fast rung's 4 ms): under a steady overload-ish load
    // batching must conserve every request and keep compliance at least
    // as good as it would be sensible to demand of the unbatched run —
    // the amortized fast rung drains 60 qps easily.
    let arrivals = generate_arrivals(&WorkloadSpec {
        base_qps: 60.0,
        duration_s: 4.0,
        pattern: Pattern::Steady,
        seed: 11,
    });
    let s = run_batched(None, &arrivals, 100.0, 8, 3.0);
    assert_eq!(s.requests, arrivals.len(), "conservation at B=8");
    assert!(
        s.slo_compliance > 0.8,
        "batched Elastico compliance {}",
        s.slo_compliance
    );
}
