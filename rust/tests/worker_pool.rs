//! Integration: the k-worker executor pool (M/G/k serving runtime), in
//! both queue disciplines.
//!
//! Uses a sleeping engine rather than [`MockEngine`]'s busy-wait so a
//! k-worker pool scales on CI runners with fewer than k cores: sleeping
//! yields the core, so the measured speedup reflects pool concurrency,
//! not host parallelism.

use std::collections::HashSet;
use std::time::Duration;

use anyhow::Result;
use compass::serving::executor::RequestEngine;
use compass::serving::pool::PoolSpec;
use compass::serving::{
    parse_pools, serve, serve_pools, Discipline, QueueBackend, ServeOptions, StaticPolicy,
};
use compass::workflows::ExecOutcome;

/// Scripted engine that sleeps out its service time (I/O-bound model).
struct SleepEngine {
    service_ms: f64,
}

impl RequestEngine for SleepEngine {
    fn execute(&mut self, _idx: usize) -> Result<ExecOutcome> {
        std::thread::sleep(Duration::from_secs_f64(self.service_ms / 1e3));
        Ok(ExecOutcome { accuracy: 0.8, success: None })
    }

    fn rungs(&self) -> usize {
        1
    }
}

/// Two-rung sleeping engine whose accuracy names the rung it ran —
/// makes the executing pool's band visible in the records.
struct RungedSleepEngine {
    service_ms: [f64; 2],
}

impl RequestEngine for RungedSleepEngine {
    fn execute(&mut self, idx: usize) -> Result<ExecOutcome> {
        std::thread::sleep(Duration::from_secs_f64(self.service_ms[idx] / 1e3));
        Ok(ExecOutcome { accuracy: if idx == 0 { 0.7 } else { 0.9 }, success: None })
    }

    fn rungs(&self) -> usize {
        2
    }
}

/// Run `n` simultaneous arrivals through a k-worker pool; returns
/// (served, rejected, makespan ms on the run clock).
fn run_pool(
    n: usize,
    workers: usize,
    service_ms: f64,
    capacity: usize,
    discipline: Discipline,
) -> (usize, usize, f64) {
    run_pool_batched(n, workers, service_ms, capacity, discipline, 1)
}

/// [`run_pool`] with an executor batch bound.
fn run_pool_batched(
    n: usize,
    workers: usize,
    service_ms: f64,
    capacity: usize,
    discipline: Discipline,
    batch: usize,
) -> (usize, usize, f64) {
    let arrivals = vec![0.0; n];
    let out = serve(
        move || Ok(SleepEngine { service_ms }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            queue_capacity: capacity,
            tick_ms: 10,
            workers,
            discipline,
            shards: 0,
            batch,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    // No record may be lost or duplicated under concurrent dequeue, and
    // the injector accounting must conserve every arrival.
    let ids: HashSet<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), out.records.len(), "duplicate records");
    assert_eq!(
        out.records.len() + out.rejected,
        n,
        "records + rejected must equal arrivals"
    );
    let makespan = out
        .records
        .iter()
        .map(|r| r.finish_ms)
        .fold(0.0_f64, f64::max);
    (out.records.len(), out.rejected, makespan)
}

#[test]
fn four_workers_cut_the_makespan_by_about_4x() {
    // 40 requests x 25 ms service: one worker needs ~1000 ms of serial
    // sleeping; four workers ~250 ms. Per-request sleep overshoot
    // inflates both sides proportionally, so the ratio is robust; demand
    // >= 3x (the acceptance bar) to leave room for scheduler noise.
    let (served1, rejected1, t1) =
        run_pool(40, 1, 25.0, 4096, Discipline::CentralFifo);
    let (served4, rejected4, t4) =
        run_pool(40, 4, 25.0, 4096, Discipline::CentralFifo);
    assert_eq!((served1, rejected1), (40, 0));
    assert_eq!((served4, rejected4), (40, 0));
    assert!(
        t1 / t4 >= 3.0,
        "k=4 should be ~4x faster: k=1 {t1:.0} ms vs k=4 {t4:.0} ms"
    );
}

#[test]
fn four_workers_scale_under_sharded_stealing_too() {
    // The sharded discipline must keep the pool speedup: simultaneous
    // arrivals round-robin over 4 shards and any early-finishing worker
    // steals, so no shard's backlog is stranded.
    let (served1, rejected1, t1) =
        run_pool(40, 1, 25.0, 4096, Discipline::ShardedSteal);
    let (served4, rejected4, t4) =
        run_pool(40, 4, 25.0, 4096, Discipline::ShardedSteal);
    assert_eq!((served1, rejected1), (40, 0));
    assert_eq!((served4, rejected4), (40, 0));
    assert!(
        t1 / t4 >= 3.0,
        "sharded k=4 should be ~4x faster: k=1 {t1:.0} ms vs k=4 {t4:.0} ms"
    );
}

#[test]
fn no_request_lost_or_duplicated_under_concurrent_dequeue() {
    // Many short requests racing 4 consumers on the shared queue.
    let arrivals: Vec<f64> = (0..300).map(|i| i as f64 * 0.0002).collect();
    let out = serve(
        || Ok(SleepEngine { service_ms: 1.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            queue_capacity: 4096,
            tick_ms: 10,
            workers: 4,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(out.rejected, 0);
    // serve() sorts records by id at merge, so this checks exactly
    // loss/duplication (ordering is restored unconditionally).
    let ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..300).collect::<Vec<u64>>(), "lost or duplicated ids");
}

#[test]
fn stealing_loses_nothing_and_never_spuriously_rejects() {
    // The steal-correctness property (acceptance): with 4 workers
    // racing over 4 shards, every request is served exactly once —
    // none lost, none duplicated — and since at most 300 requests are
    // ever buffered against a 4096-slot admission bound, the aggregate
    // depth counter may never report Full (a rejection here would be a
    // rejected-while-capacity-remains bug in the lock-free admission).
    let arrivals: Vec<f64> = (0..300).map(|i| i as f64 * 0.0002).collect();
    let out = serve(
        || Ok(SleepEngine { service_ms: 1.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            queue_capacity: 4096,
            tick_ms: 10,
            workers: 4,
            discipline: Discipline::ShardedSteal,
            shards: 0,
            batch: 1,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(out.rejected, 0, "spurious admission rejection");
    let ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..300).collect::<Vec<u64>>(), "lost or duplicated ids");
}

#[test]
fn steal_only_shards_are_fully_drained() {
    // 6 shards over 2 workers: shards 2..5 are nobody's home shard, so
    // all of their requests can only be served by stealing. Every
    // request must still come out exactly once, and the steal counter
    // must account for at least the 4/6 of requests routed to the
    // steal-only shards.
    let n = 120u64;
    let arrivals = vec![0.0; n as usize];
    let out = serve(
        || Ok(SleepEngine { service_ms: 2.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            queue_capacity: 4096,
            tick_ms: 10,
            workers: 2,
            discipline: Discipline::ShardedSteal,
            shards: 6,
            batch: 1,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(out.rejected, 0);
    let ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..n).collect::<Vec<u64>>(), "lost or duplicated ids");
    assert!(
        out.steals >= n * 4 / 6,
        "steals {} cannot cover the steal-only shards",
        out.steals
    );
}

#[test]
fn served_plus_rejected_always_sums_to_arrivals() {
    // Overload a tiny queue so admission control rejects some share;
    // accounting must stay exact with concurrent consumers, under both
    // disciplines and with batched dispatch (batches free many slots at
    // once, racing the injector harder).
    for discipline in [Discipline::CentralFifo, Discipline::ShardedSteal] {
        for batch in [1usize, 4] {
            let (served, rejected, _t) =
                run_pool_batched(60, 3, 20.0, 4, discipline, batch);
            assert!(
                rejected > 0,
                "expected overload rejections ({discipline:?}, B={batch})"
            );
            assert_eq!(served + rejected, 60, "{discipline:?}, B={batch}");
        }
    }
}

#[test]
fn batched_pool_conserves_across_workers_and_disciplines() {
    // 200 simultaneous arrivals through 4 workers dispatching batches
    // of up to 8: every request served exactly once in both disciplines
    // (batch stealing included), nothing rejected against an ample
    // admission bound.
    for discipline in [Discipline::CentralFifo, Discipline::ShardedSteal] {
        let (served, rejected, _t) =
            run_pool_batched(200, 4, 1.0, 4096, discipline, 8);
        assert_eq!((served, rejected), (200, 0), "{discipline:?}");
    }
}

#[test]
fn batch_bound_is_respected_end_to_end() {
    // With B = 8, no batch (= records sharing exact start/finish on one
    // worker) may exceed 8 requests.
    let arrivals = vec![0.0; 100];
    let out = serve(
        || Ok(SleepEngine { service_ms: 1.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            workers: 2,
            discipline: Discipline::ShardedSteal,
            batch: 8,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(out.records.len() + out.rejected, 100);
    let mut sizes: std::collections::HashMap<(u64, u64), usize> =
        std::collections::HashMap::new();
    for r in &out.records {
        *sizes
            .entry((r.start_ms.to_bits(), r.finish_ms.to_bits()))
            .or_default() += 1;
    }
    assert!(
        sizes.values().all(|&n| n <= 8),
        "a dispatch exceeded the batch bound"
    );
}

#[test]
fn single_worker_pool_preserves_fifo_service_order() {
    // k = 1 through the pool code path must still serve strictly FIFO
    // with non-overlapping service intervals (seed behavior).
    let arrivals: Vec<f64> = (0..30).map(|i| i as f64 * 0.002).collect();
    let out = serve(
        || Ok(SleepEngine { service_ms: 4.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions::default(),
    )
    .unwrap();
    assert_eq!(out.records.len(), 30);
    let mut by_start = out.records.clone();
    by_start.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
    for w in by_start.windows(2) {
        assert!(w[1].arrival_ms >= w[0].arrival_ms - 1e-6, "FIFO violated");
        assert!(w[1].start_ms >= w[0].finish_ms - 1.0, "overlap at k=1");
    }
}

// ---- heterogeneous pools (rung-aware routing, spill) -----------------

#[test]
fn single_uniform_pool_reproduces_the_k_worker_path() {
    // The live half of the parity pin (the DES half asserts bit-for-bit;
    // real threads can only assert semantics): a single homogeneous pool
    // (speed 1, offset 0) must serve everything exactly once with the
    // k-worker semantics — FIFO order at k = 1, no spill ever, and the
    // same ~4x pool speedup at k = 4 as the pre-pool runtime.
    let arrivals: Vec<f64> = (0..30).map(|i| i as f64 * 0.002).collect();
    let out = serve(
        || Ok(SleepEngine { service_ms: 4.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions { pools: vec![PoolSpec::uniform(1)], ..ServeOptions::default() },
    )
    .unwrap();
    assert_eq!(out.records.len(), 30);
    assert_eq!(out.steals, 0);
    assert_eq!(out.spills, 0, "one pool can never spill");
    assert_eq!(out.pool_served, vec![30]);
    let mut by_start = out.records.clone();
    by_start.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
    for w in by_start.windows(2) {
        assert!(w[1].arrival_ms >= w[0].arrival_ms - 1e-6, "FIFO violated");
        assert!(w[1].start_ms >= w[0].finish_ms - 1.0, "overlap at k=1");
    }
    // k = 4 through the pooled path keeps the pool speedup.
    let run_k = |pools: Vec<PoolSpec>| {
        let arrivals = vec![0.0; 40];
        let out = serve(
            || Ok(SleepEngine { service_ms: 25.0 }),
            Box::new(StaticPolicy::new(0, "only")),
            &arrivals,
            &ServeOptions { pools, ..ServeOptions::default() },
        )
        .unwrap();
        assert_eq!(out.records.len(), 40);
        out.records.iter().map(|r| r.finish_ms).fold(0.0_f64, f64::max)
    };
    let t1 = run_k(vec![PoolSpec::uniform(1)]);
    let t4 = run_k(vec![PoolSpec::uniform(4)]);
    assert!(t1 / t4 >= 3.0, "pooled k=4 should be ~4x faster: {t1:.0} vs {t4:.0}");
}

#[test]
fn rung_aware_routing_keeps_traffic_on_the_policy_rungs_pool() {
    // fast:2 owns rung 0, accurate:2 owns rung 1. A static rung-0
    // policy routes every arrival to the fast pool; the idle accurate
    // workers may only work by spilling — and whatever they serve runs
    // at THEIR band rung (visible as accuracy 0.9). Conservation and
    // per-pool accounting must hold throughout.
    let pools = parse_pools("fast:2:1.0,accurate:2:1.0").unwrap();
    let n = 120usize;
    let arrivals = vec![0.0; n];
    let out = serve_pools(
        |_pool: &PoolSpec| Ok(RungedSleepEngine { service_ms: [2.0, 2.0] }),
        Box::new(StaticPolicy::new(0, "fast")),
        &arrivals,
        &ServeOptions { pools: pools.clone(), ..ServeOptions::default() },
    )
    .unwrap();
    assert_eq!(out.records.len() + out.rejected, n, "conservation");
    assert_eq!(out.rejected, 0);
    let ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..n as u64).collect::<Vec<u64>>(), "lost or duplicated ids");
    assert_eq!(out.pool_served.iter().sum::<usize>(), n);
    // Rung-aware routing under a static rung-0 policy: EVERY arrival is
    // routed to the fast pool; the accurate pool receives none.
    assert_eq!(out.pool_arrivals, vec![n as u64, 0], "router left the band's pool");
    // With 120 simultaneous arrivals on a 2-worker home pool, the other
    // pool's 2 workers must have spilled; every spilled request executed
    // at the accurate pool's band rung.
    assert!(out.spills > 0, "idle accurate pool must spill");
    assert_eq!(
        out.records.iter().filter(|r| r.config_idx == 1).count(),
        out.pool_served[1],
        "requests served by the accurate pool ran at its band rung"
    );
    assert_eq!(
        out.records.iter().filter(|r| r.config_idx == 1).count() as u64,
        out.spills,
        "at B=1 every accurate-pool dispatch is one spill"
    );
}

#[test]
fn pool_specific_engines_receive_their_pool_spec() {
    // serve_pools hands each worker its own PoolSpec, so a harness can
    // build pool-appropriate engines: here the slow pool sleeps
    // speed_factor times longer. Everything is still served exactly
    // once and both pools contribute under a rung-1 policy (accurate
    // pool is home; fast pool spills in).
    let pools = parse_pools("fast:2:1.0,accurate:2:3.0").unwrap();
    let n = 80usize;
    let arrivals = vec![0.0; n];
    let out = serve_pools(
        |pool: &PoolSpec| {
            Ok(SleepEngine { service_ms: 2.0 * pool.speed_factor })
        },
        Box::new(StaticPolicy::new(1, "accurate")),
        &arrivals,
        &ServeOptions { pools: pools.clone(), ..ServeOptions::default() },
    )
    .unwrap();
    assert_eq!(out.records.len(), n);
    let ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..n as u64).collect::<Vec<u64>>());
    // Rung 1 routes to the accurate pool; the fast pool can only spill.
    assert!(out.spills > 0, "fast pool should scavenge the backlog");
    assert!(
        out.pool_served[0] > 0 && out.pool_served[1] > 0,
        "both pools must serve: {:?}",
        out.pool_served
    );
}

#[test]
fn pooled_accounting_stays_exact_under_admission_rejections() {
    // A tiny queue under simultaneous overload: served + rejected must
    // equal arrivals on a heterogeneous fleet too (batched and not).
    for batch in [1usize, 4] {
        let pools = parse_pools("fast:2:1.0,accurate:1:2.0").unwrap();
        let arrivals = vec![0.0; 60];
        let out = serve_pools(
            |pool: &PoolSpec| {
                Ok(SleepEngine { service_ms: 20.0 * pool.speed_factor })
            },
            Box::new(StaticPolicy::new(0, "fast")),
            &arrivals,
            &ServeOptions {
                queue_capacity: 4,
                tick_ms: 10,
                batch,
                pools: pools.clone(),
                ..ServeOptions::default()
            },
        )
        .unwrap();
        assert!(out.rejected > 0, "expected overload rejections (B={batch})");
        assert_eq!(out.records.len() + out.rejected, 60, "B={batch}");
        let ids: HashSet<u64> = out.records.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), out.records.len(), "duplicates (B={batch})");
    }
}

// ---- lock-free ring backend (--queue ring) ---------------------------

/// [`run_pool_batched`] with an explicit shard-storage backend.
fn run_pool_backend(
    n: usize,
    workers: usize,
    service_ms: f64,
    capacity: usize,
    discipline: Discipline,
    batch: usize,
    backend: QueueBackend,
) -> (usize, usize, f64) {
    let arrivals = vec![0.0; n];
    let out = serve(
        move || Ok(SleepEngine { service_ms }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            queue_capacity: capacity,
            tick_ms: 10,
            workers,
            discipline,
            shards: 0,
            batch,
            backend,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    let ids: HashSet<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), out.records.len(), "duplicate records ({backend:?})");
    assert_eq!(
        out.records.len() + out.rejected,
        n,
        "records + rejected must equal arrivals ({backend:?})"
    );
    let makespan = out
        .records
        .iter()
        .map(|r| r.finish_ms)
        .fold(0.0_f64, f64::max);
    (out.records.len(), out.rejected, makespan)
}

#[test]
fn ring_backend_serves_everything_exactly_once() {
    // The ring swap-in is invisible to the serving contract: with 4
    // workers racing over lock-free shards, every request is served
    // exactly once under both disciplines, and nothing is rejected
    // against an ample admission bound.
    for discipline in [Discipline::CentralFifo, Discipline::ShardedSteal] {
        let (served, rejected, _t) =
            run_pool_backend(300, 4, 1.0, 4096, discipline, 1, QueueBackend::Ring);
        assert_eq!((served, rejected), (300, 0), "{discipline:?}");
    }
}

#[test]
fn both_backends_conserve_under_batched_stealing() {
    // Batched dispatch (B=8) against 4 workers exercises the one-CAS
    // steal-half reservation on the ring and the locked front-run on the
    // mutex shards — conservation must hold identically for both.
    for backend in [QueueBackend::Mutex, QueueBackend::Ring] {
        let (served, rejected, _t) = run_pool_backend(
            200,
            4,
            1.0,
            4096,
            Discipline::ShardedSteal,
            8,
            backend,
        );
        assert_eq!((served, rejected), (200, 0), "{backend:?}");
    }
}

#[test]
fn ring_backend_accounting_stays_exact_under_rejections() {
    // A tiny queue under simultaneous overload: the ring's per-shard
    // bound adds a second rejection source (shard ring full as well as
    // the aggregate capacity), and the push rollback must keep
    // served + rejected == arrivals exact anyway.
    for batch in [1usize, 4] {
        let (served, rejected, _t) = run_pool_backend(
            60,
            3,
            20.0,
            4,
            Discipline::ShardedSteal,
            batch,
            QueueBackend::Ring,
        );
        assert!(rejected > 0, "expected overload rejections (B={batch})");
        assert_eq!(served + rejected, 60, "B={batch}");
    }
}

#[test]
fn ring_backend_keeps_the_pool_speedup() {
    // The lock-free hot path must not cost the pool its concurrency:
    // k=4 over ring shards keeps the ~4x speedup of the mutex baseline.
    let (served1, rejected1, t1) =
        run_pool_backend(40, 1, 25.0, 4096, Discipline::ShardedSteal, 1, QueueBackend::Ring);
    let (served4, rejected4, t4) =
        run_pool_backend(40, 4, 25.0, 4096, Discipline::ShardedSteal, 1, QueueBackend::Ring);
    assert_eq!((served1, rejected1), (40, 0));
    assert_eq!((served4, rejected4), (40, 0));
    assert!(
        t1 / t4 >= 3.0,
        "ring k=4 should be ~4x faster: k=1 {t1:.0} ms vs k=4 {t4:.0} ms"
    );
}

#[test]
fn sharded_single_shard_behaves_like_the_central_fifo() {
    // Live k=1 parity (the DES asserts bit-for-bit; real threads can
    // only assert semantics): one shard + one worker must preserve
    // strict FIFO order, serve everything, and never steal.
    let arrivals: Vec<f64> = (0..30).map(|i| i as f64 * 0.002).collect();
    let out = serve(
        || Ok(SleepEngine { service_ms: 4.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            discipline: Discipline::ShardedSteal,
            shards: 1,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(out.records.len(), 30);
    assert_eq!(out.steals, 0, "one shard can never steal");
    let mut by_start = out.records.clone();
    by_start.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
    for w in by_start.windows(2) {
        assert!(w[1].arrival_ms >= w[0].arrival_ms - 1e-6, "FIFO violated");
        assert!(w[1].start_ms >= w[0].finish_ms - 1.0, "overlap at k=1");
    }
}
