//! Integration: the k-worker executor pool (M/G/k serving runtime), in
//! both queue disciplines.
//!
//! Uses a sleeping engine rather than [`MockEngine`]'s busy-wait so a
//! k-worker pool scales on CI runners with fewer than k cores: sleeping
//! yields the core, so the measured speedup reflects pool concurrency,
//! not host parallelism.

use std::collections::HashSet;
use std::time::Duration;

use anyhow::Result;
use compass::serving::executor::RequestEngine;
use compass::serving::{serve, Discipline, ServeOptions, StaticPolicy};
use compass::workflows::ExecOutcome;

/// Scripted engine that sleeps out its service time (I/O-bound model).
struct SleepEngine {
    service_ms: f64,
}

impl RequestEngine for SleepEngine {
    fn execute(&mut self, _idx: usize) -> Result<ExecOutcome> {
        std::thread::sleep(Duration::from_secs_f64(self.service_ms / 1e3));
        Ok(ExecOutcome { accuracy: 0.8, success: None })
    }

    fn rungs(&self) -> usize {
        1
    }
}

/// Run `n` simultaneous arrivals through a k-worker pool; returns
/// (served, rejected, makespan ms on the run clock).
fn run_pool(
    n: usize,
    workers: usize,
    service_ms: f64,
    capacity: usize,
    discipline: Discipline,
) -> (usize, usize, f64) {
    run_pool_batched(n, workers, service_ms, capacity, discipline, 1)
}

/// [`run_pool`] with an executor batch bound.
fn run_pool_batched(
    n: usize,
    workers: usize,
    service_ms: f64,
    capacity: usize,
    discipline: Discipline,
    batch: usize,
) -> (usize, usize, f64) {
    let arrivals = vec![0.0; n];
    let out = serve(
        move || Ok(SleepEngine { service_ms }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            queue_capacity: capacity,
            tick_ms: 10,
            workers,
            discipline,
            shards: 0,
            batch,
        },
    )
    .unwrap();
    // No record may be lost or duplicated under concurrent dequeue, and
    // the injector accounting must conserve every arrival.
    let ids: HashSet<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), out.records.len(), "duplicate records");
    assert_eq!(
        out.records.len() + out.rejected,
        n,
        "records + rejected must equal arrivals"
    );
    let makespan = out
        .records
        .iter()
        .map(|r| r.finish_ms)
        .fold(0.0_f64, f64::max);
    (out.records.len(), out.rejected, makespan)
}

#[test]
fn four_workers_cut_the_makespan_by_about_4x() {
    // 40 requests x 25 ms service: one worker needs ~1000 ms of serial
    // sleeping; four workers ~250 ms. Per-request sleep overshoot
    // inflates both sides proportionally, so the ratio is robust; demand
    // >= 3x (the acceptance bar) to leave room for scheduler noise.
    let (served1, rejected1, t1) =
        run_pool(40, 1, 25.0, 4096, Discipline::CentralFifo);
    let (served4, rejected4, t4) =
        run_pool(40, 4, 25.0, 4096, Discipline::CentralFifo);
    assert_eq!((served1, rejected1), (40, 0));
    assert_eq!((served4, rejected4), (40, 0));
    assert!(
        t1 / t4 >= 3.0,
        "k=4 should be ~4x faster: k=1 {t1:.0} ms vs k=4 {t4:.0} ms"
    );
}

#[test]
fn four_workers_scale_under_sharded_stealing_too() {
    // The sharded discipline must keep the pool speedup: simultaneous
    // arrivals round-robin over 4 shards and any early-finishing worker
    // steals, so no shard's backlog is stranded.
    let (served1, rejected1, t1) =
        run_pool(40, 1, 25.0, 4096, Discipline::ShardedSteal);
    let (served4, rejected4, t4) =
        run_pool(40, 4, 25.0, 4096, Discipline::ShardedSteal);
    assert_eq!((served1, rejected1), (40, 0));
    assert_eq!((served4, rejected4), (40, 0));
    assert!(
        t1 / t4 >= 3.0,
        "sharded k=4 should be ~4x faster: k=1 {t1:.0} ms vs k=4 {t4:.0} ms"
    );
}

#[test]
fn no_request_lost_or_duplicated_under_concurrent_dequeue() {
    // Many short requests racing 4 consumers on the shared queue.
    let arrivals: Vec<f64> = (0..300).map(|i| i as f64 * 0.0002).collect();
    let out = serve(
        || Ok(SleepEngine { service_ms: 1.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            queue_capacity: 4096,
            tick_ms: 10,
            workers: 4,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(out.rejected, 0);
    // serve() sorts records by id at merge, so this checks exactly
    // loss/duplication (ordering is restored unconditionally).
    let ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..300).collect::<Vec<u64>>(), "lost or duplicated ids");
}

#[test]
fn stealing_loses_nothing_and_never_spuriously_rejects() {
    // The steal-correctness property (acceptance): with 4 workers
    // racing over 4 shards, every request is served exactly once —
    // none lost, none duplicated — and since at most 300 requests are
    // ever buffered against a 4096-slot admission bound, the aggregate
    // depth counter may never report Full (a rejection here would be a
    // rejected-while-capacity-remains bug in the lock-free admission).
    let arrivals: Vec<f64> = (0..300).map(|i| i as f64 * 0.0002).collect();
    let out = serve(
        || Ok(SleepEngine { service_ms: 1.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            queue_capacity: 4096,
            tick_ms: 10,
            workers: 4,
            discipline: Discipline::ShardedSteal,
            shards: 0,
            batch: 1,
        },
    )
    .unwrap();
    assert_eq!(out.rejected, 0, "spurious admission rejection");
    let ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..300).collect::<Vec<u64>>(), "lost or duplicated ids");
}

#[test]
fn steal_only_shards_are_fully_drained() {
    // 6 shards over 2 workers: shards 2..5 are nobody's home shard, so
    // all of their requests can only be served by stealing. Every
    // request must still come out exactly once, and the steal counter
    // must account for at least the 4/6 of requests routed to the
    // steal-only shards.
    let n = 120u64;
    let arrivals = vec![0.0; n as usize];
    let out = serve(
        || Ok(SleepEngine { service_ms: 2.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            queue_capacity: 4096,
            tick_ms: 10,
            workers: 2,
            discipline: Discipline::ShardedSteal,
            shards: 6,
            batch: 1,
        },
    )
    .unwrap();
    assert_eq!(out.rejected, 0);
    let ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..n).collect::<Vec<u64>>(), "lost or duplicated ids");
    assert!(
        out.steals >= n * 4 / 6,
        "steals {} cannot cover the steal-only shards",
        out.steals
    );
}

#[test]
fn served_plus_rejected_always_sums_to_arrivals() {
    // Overload a tiny queue so admission control rejects some share;
    // accounting must stay exact with concurrent consumers, under both
    // disciplines and with batched dispatch (batches free many slots at
    // once, racing the injector harder).
    for discipline in [Discipline::CentralFifo, Discipline::ShardedSteal] {
        for batch in [1usize, 4] {
            let (served, rejected, _t) =
                run_pool_batched(60, 3, 20.0, 4, discipline, batch);
            assert!(
                rejected > 0,
                "expected overload rejections ({discipline:?}, B={batch})"
            );
            assert_eq!(served + rejected, 60, "{discipline:?}, B={batch}");
        }
    }
}

#[test]
fn batched_pool_conserves_across_workers_and_disciplines() {
    // 200 simultaneous arrivals through 4 workers dispatching batches
    // of up to 8: every request served exactly once in both disciplines
    // (batch stealing included), nothing rejected against an ample
    // admission bound.
    for discipline in [Discipline::CentralFifo, Discipline::ShardedSteal] {
        let (served, rejected, _t) =
            run_pool_batched(200, 4, 1.0, 4096, discipline, 8);
        assert_eq!((served, rejected), (200, 0), "{discipline:?}");
    }
}

#[test]
fn batch_bound_is_respected_end_to_end() {
    // With B = 8, no batch (= records sharing exact start/finish on one
    // worker) may exceed 8 requests.
    let arrivals = vec![0.0; 100];
    let out = serve(
        || Ok(SleepEngine { service_ms: 1.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            workers: 2,
            discipline: Discipline::ShardedSteal,
            batch: 8,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(out.records.len() + out.rejected, 100);
    let mut sizes: std::collections::HashMap<(u64, u64), usize> =
        std::collections::HashMap::new();
    for r in &out.records {
        *sizes
            .entry((r.start_ms.to_bits(), r.finish_ms.to_bits()))
            .or_default() += 1;
    }
    assert!(
        sizes.values().all(|&n| n <= 8),
        "a dispatch exceeded the batch bound"
    );
}

#[test]
fn single_worker_pool_preserves_fifo_service_order() {
    // k = 1 through the pool code path must still serve strictly FIFO
    // with non-overlapping service intervals (seed behavior).
    let arrivals: Vec<f64> = (0..30).map(|i| i as f64 * 0.002).collect();
    let out = serve(
        || Ok(SleepEngine { service_ms: 4.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions::default(),
    )
    .unwrap();
    assert_eq!(out.records.len(), 30);
    let mut by_start = out.records.clone();
    by_start.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
    for w in by_start.windows(2) {
        assert!(w[1].arrival_ms >= w[0].arrival_ms - 1e-6, "FIFO violated");
        assert!(w[1].start_ms >= w[0].finish_ms - 1.0, "overlap at k=1");
    }
}

#[test]
fn sharded_single_shard_behaves_like_the_central_fifo() {
    // Live k=1 parity (the DES asserts bit-for-bit; real threads can
    // only assert semantics): one shard + one worker must preserve
    // strict FIFO order, serve everything, and never steal.
    let arrivals: Vec<f64> = (0..30).map(|i| i as f64 * 0.002).collect();
    let out = serve(
        || Ok(SleepEngine { service_ms: 4.0 }),
        Box::new(StaticPolicy::new(0, "only")),
        &arrivals,
        &ServeOptions {
            discipline: Discipline::ShardedSteal,
            shards: 1,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(out.records.len(), 30);
    assert_eq!(out.steals, 0, "one shard can never steal");
    let mut by_start = out.records.clone();
    by_start.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
    for w in by_start.windows(2) {
        assert!(w[1].arrival_ms >= w[0].arrival_ms - 1e-6, "FIFO violated");
        assert!(w[1].start_ms >= w[0].finish_ms - 1.0, "overlap at k=1");
    }
}
