//! Integration: load AOT artifacts through PJRT and execute every model
//! kind end-to-end. Requires `make artifacts` (skips gracefully when the
//! artifacts directory is absent, e.g. in a source-only checkout).
//!
//! All three tests are `#[ignore]`d: the offline build links the PJRT
//! stub (`runtime::xla_stub`), so even with artifacts present there is
//! no real backend to execute them. Run with `--ignored` on a build
//! carrying the real `xla` crate.

use compass::configspace::rag_space;
use compass::runtime::{artifacts_dir, ArtifactLib, TensorIn};
use compass::util::Rng;
use compass::workflows::rag::corpus::{Corpus, CORPUS_N, EMBED_D};
use compass::workflows::rag::RagWorkflow;
use compass::workflows::Workflow;

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[test]
#[ignore = "needs real PJRT (xla crate) + `make artifacts`; offline build links the stub"]
fn retriever_executes_and_ranks_planted_doc() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let lib = ArtifactLib::load(&artifacts_dir(), Some(&["retriever"])).unwrap();
    let corpus = Corpus::generate(3);
    let mut rng = Rng::new(5);

    let mut hits_at_10 = 0;
    let trials = 30;
    for _ in 0..trials {
        let q = corpus.sample_query(&mut rng);
        let outs = lib
            .execute(
                "retriever",
                &[
                    TensorIn::F32(&corpus.embeddings, &[CORPUS_N, EMBED_D]),
                    TensorIn::F32(&q.embedding, &[EMBED_D]),
                ],
            )
            .unwrap();
        let vals = outs[0].as_f32().unwrap();
        let idx = outs[1].as_i32().unwrap();
        assert_eq!(vals.len(), 50);
        assert_eq!(idx.len(), 50);
        // Scores descending.
        for w in vals.windows(2) {
            assert!(w[0] >= w[1]);
        }
        if idx[..10].contains(&(q.truth as i32)) {
            hits_at_10 += 1;
        }
    }
    // Calibrated recall@10 ≈ 0.85; even pessimistically > 0.5 here.
    assert!(hits_at_10 > trials / 2, "recall@10 {hits_at_10}/{trials}");
}

#[test]
#[ignore = "needs real PJRT (xla crate) + `make artifacts`; offline build links the stub"]
fn generator_reranker_detector_execute() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let lib = ArtifactLib::load(
        &artifacts_dir(),
        Some(&["gen-64", "rr-48", "det-n", "ver-m"]),
    )
    .unwrap();

    // Generator: fused prefill+decode returns 16 tokens + confidence.
    let tokens: Vec<i32> = (0..64).map(|i| (i * 7) % 256).collect();
    let outs = lib
        .execute("gen-64", &[TensorIn::I32(&tokens, &[64])])
        .unwrap();
    let gen = outs[0].as_i32().unwrap();
    let score = outs[1].as_f32().unwrap()[0];
    assert_eq!(gen.len(), 16);
    assert!(gen.iter().all(|&t| (0..256).contains(&t)));
    assert!((0.0..=1.0).contains(&score), "confidence {score}");
    // Determinism: same prompt, same tokens.
    let outs2 = lib
        .execute("gen-64", &[TensorIn::I32(&tokens, &[64])])
        .unwrap();
    assert_eq!(outs2[0].as_i32().unwrap(), gen);

    // Reranker: 5 scores.
    let q: Vec<i32> = (0..16).collect();
    let d: Vec<i32> = (0..5 * 32).map(|i| i % 256).collect();
    let outs = lib
        .execute(
            "rr-48",
            &[TensorIn::I32(&q, &[16]), TensorIn::I32(&d, &[5, 32])],
        )
        .unwrap();
    assert_eq!(outs[0].as_f32().unwrap().len(), 5);

    // Detector + verifier.
    let img = vec![0.1f32; 32 * 32 * 3];
    let outs = lib
        .execute("det-n", &[TensorIn::F32(&img, &[32, 32, 3])])
        .unwrap();
    assert_eq!(outs[0].as_f32().unwrap().len(), 64);
    assert_eq!(outs[1].as_f32().unwrap().len(), 8);
    let outs = lib
        .execute("ver-m", &[TensorIn::F32(&img, &[32, 32, 3])])
        .unwrap();
    assert_eq!(outs[0].as_f32().unwrap().len(), 1);
}

#[test]
#[ignore = "needs real PJRT (xla crate) + `make artifacts`; offline build links the stub"]
fn rag_workflow_runs_all_stages() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let space = rag_space();
    // A mid-ladder config: (gen-96, k=10, rk=3, rr-48).
    let cfg = vec![1, 2, 1, 0];
    assert!(space.valid(&cfg));
    let mut wf = RagWorkflow::load_subset(&artifacts_dir(), &space, &[cfg.clone()], 11).unwrap();
    let mut successes = 0;
    for _ in 0..10 {
        let out = wf.run(&space, &cfg).unwrap();
        assert!((0.0..=1.0).contains(&out.accuracy));
        if out.success == Some(true) {
            successes += 1;
        }
    }
    // gen-96 quality 0.72 and hit-rate ~0.8: expect a majority successes.
    assert!(successes >= 3, "successes {successes}/10");
}
