//! Integration: the resilience plane under chaos — panic-safe workers,
//! engine-error containment, bounded retries, windowed dark pools with
//! failover-and-recover, flaky-engine windows, and circuit breakers —
//! in BOTH executors, with the extended conservation law
//! `served + rejected + failed == arrivals` holding everywhere.
//!
//! Two pins anchor the PR:
//!
//! 1. **Disabled parity** — `ResilienceConfig::default()` (off) plus an
//!    empty fault plan reproduces the plain DES engine bit for bit, and
//!    the live server with resilience off reports all-zero resilience
//!    counters.
//! 2. **Failover beats drain** — under the same windowed dark fault,
//!    same arrivals and same seed, resilience-on yields strictly higher
//!    SLO goodput (`in-SLO served / arrivals`) than resilience-off, in
//!    both the DES and the live runtime.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use compass::planner::{derive_plan, AqmParams, LatencyProfile, Plan, ProfiledConfig};
use compass::serving::executor::RequestEngine;
use compass::serving::{parse_pools, serve, ResilienceConfig, ServeOptions, StaticPolicy, Topology};
use compass::sim::{simulate_topology, simulate_topology_resilient, LognormalService, SimOutcome};
use compass::workflows::ExecOutcome;
use compass::workload::{Fault, FaultPlan};

/// Synthetic two-rung plan (fast 20 ms, accurate 90 ms), same idiom as
/// the scenario suite.
fn plan2() -> Plan {
    let mk = |label: &str, acc: f64, mean: f64, p95: f64| ProfiledConfig {
        config: vec![],
        label: label.into(),
        accuracy: acc,
        latency: LatencyProfile { mean_ms: mean, p50_ms: mean, p95_ms: p95, runs: 10 },
    };
    derive_plan(
        &[mk("fast", 0.76, 20.0, 28.0), mk("accurate", 0.85, 90.0, 120.0)],
        AqmParams::for_slo(300.0),
    )
}

fn steady_arrivals(qps: f64, dur: f64) -> Vec<f64> {
    let n = (qps * dur) as usize;
    (0..n).map(|i| i as f64 / qps).collect()
}

/// Fraction of *arrivals* answered within `slo_ms` — unlike plain
/// compliance (computed over survivors), a drain-rejected or failed
/// request counts against goodput, so shedding load cannot flatter it.
fn slo_goodput(records: &[compass::metrics::RequestRecord], arrivals: usize, slo_ms: f64) -> f64 {
    if arrivals == 0 {
        return 0.0;
    }
    records.iter().filter(|r| r.latency_ms() <= slo_ms).count() as f64 / arrivals as f64
}

fn conservation(label: &str, served: usize, rejected: usize, failed: usize, arrivals: usize) {
    assert_eq!(
        served + rejected + failed,
        arrivals,
        "{label}: served {served} + rejected {rejected} + failed {failed} != arrivals {arrivals}"
    );
}

fn unique_ids(records: &[compass::metrics::RequestRecord], label: &str) {
    let mut ids: Vec<u64> = records.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    let n = ids.len();
    ids.dedup();
    assert_eq!(ids.len(), n, "{label}: a retried request was served twice");
}

// ---------------------------------------------------------------------
// Scripted engines
// ---------------------------------------------------------------------

/// Sleeps out a fixed service time, always succeeds.
struct SleepEngine {
    service_ms: f64,
}

impl RequestEngine for SleepEngine {
    fn execute(&mut self, _idx: usize) -> Result<ExecOutcome> {
        std::thread::sleep(Duration::from_secs_f64(self.service_ms / 1e3));
        Ok(ExecOutcome { accuracy: 0.8, success: None })
    }

    fn rungs(&self) -> usize {
        2
    }
}

/// Returns `Err` for the first `budget` executions across ALL workers
/// (the shared counter makes the failure count exact), then succeeds.
struct ErrEngine {
    budget: Arc<AtomicUsize>,
}

impl RequestEngine for ErrEngine {
    fn execute(&mut self, _idx: usize) -> Result<ExecOutcome> {
        std::thread::sleep(Duration::from_millis(1));
        if take_token(&self.budget) {
            anyhow::bail!("injected engine error");
        }
        Ok(ExecOutcome { accuracy: 0.8, success: None })
    }

    fn rungs(&self) -> usize {
        2
    }
}

/// Panics for the first `budget` executions across ALL workers, then
/// succeeds — exercises the supervisor's catch-and-respawn path.
struct PanicEngine {
    budget: Arc<AtomicUsize>,
}

impl RequestEngine for PanicEngine {
    fn execute(&mut self, _idx: usize) -> Result<ExecOutcome> {
        std::thread::sleep(Duration::from_millis(1));
        if take_token(&self.budget) {
            panic!("injected worker panic");
        }
        Ok(ExecOutcome { accuracy: 0.8, success: None })
    }

    fn rungs(&self) -> usize {
        2
    }
}

/// Decrement `budget` if positive; true while tokens remain.
fn take_token(budget: &AtomicUsize) -> bool {
    budget
        .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
        .is_ok()
}

// ---------------------------------------------------------------------
// Live executor: error containment and panic-safe supervision
// ---------------------------------------------------------------------

#[test]
fn live_engine_errors_no_longer_abort_the_run() {
    // Regression (pre-resilience bug): an engine `Err` propagated
    // through `?` in the worker loop, silently dropping every request
    // still queued behind it and poisoning the join. Now the error
    // fails only its own request — even with resilience disabled.
    let n = 120;
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.002).collect();
    let budget = Arc::new(AtomicUsize::new(3));
    let b = budget.clone();
    let out = serve(
        move || Ok(ErrEngine { budget: b.clone() }),
        Box::new(StaticPolicy::new(0, "fast")),
        &arrivals,
        &ServeOptions { workers: 2, ..ServeOptions::default() },
    )
    .expect("an engine error must not abort serve()");
    conservation("live err", out.records.len(), out.rejected, out.failed, n);
    assert_eq!(out.failed, 3, "each injected error fails exactly its own request");
    assert_eq!(out.retries, 0, "resilience off: no retries");
    assert_eq!(budget.load(Ordering::SeqCst), 0, "all injected errors fired");
    unique_ids(&out.records, "live err");
}

#[test]
fn live_panics_are_caught_and_the_worker_respawns() {
    let n = 120;
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.002).collect();
    let budget = Arc::new(AtomicUsize::new(2));
    let b = budget.clone();
    let out = serve(
        move || Ok(PanicEngine { budget: b.clone() }),
        Box::new(StaticPolicy::new(0, "fast")),
        &arrivals,
        &ServeOptions { workers: 2, ..ServeOptions::default() },
    )
    .expect("a worker panic must not abort serve()");
    conservation("live panic", out.records.len(), out.rejected, out.failed, n);
    assert_eq!(out.panics_recovered, 2, "both injected panics were supervised");
    assert_eq!(out.failed, 2, "resilience off: a panicked request fails terminally");
    assert!(out.records.len() >= n - 2, "the respawned engine kept serving");
    unique_ids(&out.records, "live panic");
}

#[test]
fn live_retries_recover_errors_when_resilience_is_on() {
    let n = 120;
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.002).collect();
    let budget = Arc::new(AtomicUsize::new(3));
    let b = budget.clone();
    let out = serve(
        move || Ok(ErrEngine { budget: b.clone() }),
        Box::new(StaticPolicy::new(0, "fast")),
        &arrivals,
        &ServeOptions {
            workers: 2,
            resilience: ResilienceConfig::enabled(),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    conservation("live retry", out.records.len(), out.rejected, out.failed, n);
    assert!(out.retries >= 1, "an injected error must re-enqueue, not fail outright");
    // Every error is either retried into a success or (if one request
    // drew several error tokens) counted failed — never lost.
    assert!(out.records.len() + out.failed >= n);
    unique_ids(&out.records, "live retry");
}

#[test]
fn live_panics_are_retried_when_resilience_is_on() {
    let n = 120;
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.002).collect();
    let budget = Arc::new(AtomicUsize::new(2));
    let b = budget.clone();
    let out = serve(
        move || Ok(PanicEngine { budget: b.clone() }),
        Box::new(StaticPolicy::new(0, "fast")),
        &arrivals,
        &ServeOptions {
            workers: 2,
            resilience: ResilienceConfig::enabled(),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    conservation("live panic+retry", out.records.len(), out.rejected, out.failed, n);
    assert_eq!(out.panics_recovered, 2);
    assert!(out.retries >= 1, "a supervised panic must re-enqueue its request");
    unique_ids(&out.records, "live panic+retry");
}

#[test]
fn live_resilience_off_reports_zero_counters() {
    let n = 60;
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.003).collect();
    let out = serve(
        move || Ok(SleepEngine { service_ms: 1.0 }),
        Box::new(StaticPolicy::new(0, "fast")),
        &arrivals,
        &ServeOptions { workers: 2, ..ServeOptions::default() },
    )
    .unwrap();
    conservation("live off", out.records.len(), out.rejected, out.failed, n);
    let counters = (out.failed, out.retries, out.panics_recovered, out.timeouts, out.failovers);
    assert_eq!(counters, (0, 0, 0, 0, 0), "disabled resilience must not count anything");
    assert_eq!(out.breaker_trips, 0);
}

// ---------------------------------------------------------------------
// Live executor: flaky windows and windowed dark pools
// ---------------------------------------------------------------------

#[test]
fn live_flaky_window_is_deterministic_and_conserves() {
    // The flaky coin hashes (pool, id, attempt) with the window keyed
    // on ARRIVAL time, so the exact failure set is computable up front.
    let n = 200;
    let arrivals: Vec<f64> = (0..n as u64).map(|i| i as f64 * 0.002).collect();
    let faults =
        FaultPlan::none().with(Fault::EngineFlaky { pool: 0, rate: 0.3, from_s: 0.1, to_s: 0.3 });
    let expect_failed = (0..n as u64)
        .filter(|&i| faults.flaky_fails(0, i, 0, arrivals[i as usize] * 1e3))
        .count();
    assert!(expect_failed >= 1, "the window must catch at least one arrival");
    let out = serve(
        move || Ok(SleepEngine { service_ms: 1.0 }),
        Box::new(StaticPolicy::new(0, "fast")),
        &arrivals,
        &ServeOptions { workers: 2, faults: faults.clone(), ..ServeOptions::default() },
    )
    .unwrap();
    conservation("live flaky", out.records.len(), out.rejected, out.failed, n);
    assert_eq!(
        out.failed, expect_failed,
        "resilience off: exactly the coin-failed arrivals fail terminally"
    );
    unique_ids(&out.records, "live flaky");
}

#[test]
fn live_windowed_dark_fails_over_and_recovers() {
    let pools = parse_pools("fast:2:1.0,acc:2:1.0").unwrap();
    let n = 300;
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.003).collect();
    let faults = FaultPlan::none().with(Fault::PoolDark { pool: 1, at_s: 0.2, until_s: Some(0.6) });
    let out = serve(
        move || Ok(SleepEngine { service_ms: 2.0 }),
        Box::new(StaticPolicy::new(1, "acc")),
        &arrivals,
        &ServeOptions {
            pools: pools.clone(),
            faults: faults.clone(),
            resilience: ResilienceConfig::enabled(),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    conservation("live dark failover", out.records.len(), out.rejected, out.failed, n);
    assert!(out.failovers >= 1, "in-window load must remap to the surviving pool");
    assert_eq!(out.rejected, 0, "failover replaces drain-rejection");
    unique_ids(&out.records, "live dark failover");
}

// ---------------------------------------------------------------------
// DES mirror: parity, determinism, chaos conservation
// ---------------------------------------------------------------------

#[test]
fn des_disabled_resilience_is_bit_identical_to_the_plain_engine() {
    let plan = plan2();
    let arr = steady_arrivals(12.0, 60.0);
    let svc = LognormalService::from_plan(&plan, 0.25);
    let topo = Topology::uniform(2, 2);
    let mut p1 = compass::serving::ElasticoPolicy::new(plan.clone());
    let base = simulate_topology(&arr, &plan, &mut p1, &svc, 42, &topo, 1);
    let mut p2 = compass::serving::ElasticoPolicy::new(plan.clone());
    let res = simulate_topology_resilient(
        &arr,
        &plan,
        &mut p2,
        &svc,
        42,
        &topo,
        1,
        &FaultPlan::none(),
        &ResilienceConfig::default(),
    );
    assert_eq!(base.records.len(), res.records.len());
    for (x, y) in base.records.iter().zip(&res.records) {
        assert_eq!(x, y, "disabled resilience must not perturb the DES");
    }
    assert_eq!(base.switches.len(), res.switches.len());
    let counters = (res.failed, res.retries, res.timeouts, res.breaker_trips, res.failovers);
    assert_eq!(counters, (0, 0, 0, 0, 0));
}

#[test]
fn des_windowed_dark_disabled_pauses_and_serves_the_backlog() {
    // Resilience OFF + a finite window = pause, not drain: the pool
    // holds its queue through the outage and serves everything late.
    let pools = parse_pools("fast:2:1.0,acc:2:1.0").unwrap();
    let topo = Topology::from_pools(&pools, 0.0).unwrap();
    let plan = plan2();
    let svc = LognormalService::from_plan(&plan, 0.10);
    let arr = steady_arrivals(8.0, 90.0);
    let faults =
        FaultPlan::none().with(Fault::PoolDark { pool: 1, at_s: 20.0, until_s: Some(60.0) });
    let mut p = StaticPolicy::new(1, "acc");
    let out = simulate_topology_resilient(
        &arr,
        &plan,
        &mut p,
        &svc,
        42,
        &topo,
        1,
        &faults,
        &ResilienceConfig::default(),
    );
    conservation("des dark pause", out.records.len(), out.rejected, out.failed, arr.len());
    assert_eq!(out.records.len(), arr.len(), "a finite window rejects nothing");
    let worst = out.records.iter().map(|r| r.latency_ms()).fold(0.0, f64::max);
    assert!(worst >= 10_000.0, "in-window arrivals must wait out the outage (worst {worst} ms)");
}

#[test]
fn des_windowed_dark_resilient_fails_over_and_recovers() {
    let pools = parse_pools("fast:2:1.0,acc:2:1.0").unwrap();
    let topo = Topology::from_pools(&pools, 0.0).unwrap();
    let plan = plan2();
    let svc = LognormalService::from_plan(&plan, 0.10);
    let arr = steady_arrivals(8.0, 90.0);
    let faults =
        FaultPlan::none().with(Fault::PoolDark { pool: 1, at_s: 20.0, until_s: Some(60.0) });
    let mut p = StaticPolicy::new(1, "acc");
    let out = simulate_topology_resilient(
        &arr,
        &plan,
        &mut p,
        &svc,
        42,
        &topo,
        1,
        &faults,
        &ResilienceConfig::enabled(),
    );
    conservation("des dark failover", out.records.len(), out.rejected, out.failed, arr.len());
    assert!(out.failovers >= 1, "in-window load must remap to the surviving pool");
    assert_eq!(out.rejected, 0);
    // Recovery: the run's tail (post-window arrivals) is healthy again —
    // late arrivals come back within the SLO instead of queueing behind
    // a dead pool.
    let tail: Vec<_> = out.records.iter().filter(|r| r.arrival_ms >= 70_000.0).collect();
    assert!(!tail.is_empty());
    assert!(
        tail.iter().all(|r| r.latency_ms() <= 5_000.0),
        "post-recovery arrivals must not inherit the outage backlog"
    );
    unique_ids(&out.records, "des dark failover");
}

#[test]
fn des_flaky_retries_are_deterministic() {
    let topo = Topology::uniform(2, 2);
    let plan = plan2();
    let svc = LognormalService::from_plan(&plan, 0.10);
    let arr = steady_arrivals(10.0, 60.0);
    let faults = FaultPlan::none().with(Fault::EngineFlaky {
        pool: 0,
        rate: 0.25,
        from_s: 15.0,
        to_s: 45.0,
    });
    let run = |cfg: &ResilienceConfig| -> SimOutcome {
        let mut p = StaticPolicy::new(0, "fast");
        simulate_topology_resilient(&arr, &plan, &mut p, &svc, 42, &topo, 1, &faults, cfg)
    };
    // Resilience off: flakes are terminal failures, no retries.
    let off = run(&ResilienceConfig::default());
    conservation("des flaky off", off.records.len(), off.rejected, off.failed, arr.len());
    assert!(off.failed >= 1, "the window must flake at least one request");
    assert_eq!(off.retries, 0);
    // Resilience on: flakes retry (fresh attempt => fresh coin) and
    // mostly recover.
    let on = run(&ResilienceConfig::enabled());
    conservation("des flaky on", on.records.len(), on.rejected, on.failed, arr.len());
    assert!(on.retries >= 1);
    assert!(on.records.len() > off.records.len(), "retries must recover some flaked requests");
    unique_ids(&on.records, "des flaky on");
    // Bit-identical replay: the whole chaos run is deterministic.
    let again = run(&ResilienceConfig::enabled());
    assert_eq!(on.records.len(), again.records.len());
    for (x, y) in on.records.iter().zip(&again.records) {
        assert_eq!(x, y, "chaos DES must replay bit-identically");
    }
    assert_eq!(
        (on.failed, on.retries, on.timeouts, on.breaker_trips, on.failovers),
        (again.failed, again.retries, again.timeouts, again.breaker_trips, again.failovers)
    );
}

#[test]
fn des_breaker_trips_and_routes_around_a_failing_pool() {
    // A fully flaky window on the home pool: the error EWMA trips the
    // breaker, retries route to the surviving pool, and after the
    // window + open interval a half-open probe recloses it.
    let pools = parse_pools("fast:2:1.0,acc:2:1.0").unwrap();
    let topo = Topology::from_pools(&pools, 0.0).unwrap();
    let plan = plan2();
    let svc = LognormalService::from_plan(&plan, 0.10);
    let arr = steady_arrivals(10.0, 60.0);
    let faults =
        FaultPlan::none().with(Fault::EngineFlaky { pool: 0, rate: 1.0, from_s: 10.0, to_s: 30.0 });
    let cfg = ResilienceConfig {
        breaker_min_samples: 4,
        breaker_alpha: 0.5,
        breaker_threshold: 0.4,
        breaker_open_ms: 2_000.0,
        ..ResilienceConfig::enabled()
    };
    let mut p = StaticPolicy::new(0, "fast");
    let out = simulate_topology_resilient(&arr, &plan, &mut p, &svc, 42, &topo, 1, &faults, &cfg);
    conservation("des breaker", out.records.len(), out.rejected, out.failed, arr.len());
    assert!(out.breaker_trips >= 1, "a 100% error window must trip the breaker");
    assert!(out.failovers >= 1, "an open breaker must route load to the other pool");
    // Reclose: post-window arrivals to the home pool are served again.
    let tail: Vec<_> = out.records.iter().filter(|r| r.arrival_ms >= 40_000.0).collect();
    assert!(tail.len() >= 10, "the half-open probe must reclose the breaker after the window");
    unique_ids(&out.records, "des breaker");
}

// ---------------------------------------------------------------------
// The acceptance pin: failover strictly beats drain under the same
// windowed dark fault, in BOTH executors.
// ---------------------------------------------------------------------

#[test]
fn des_failover_goodput_strictly_beats_drain_under_dark_window() {
    let pools = parse_pools("fast:2:1.0,acc:2:1.0").unwrap();
    let topo = Topology::from_pools(&pools, 0.0).unwrap();
    let plan = plan2();
    let svc = LognormalService::from_plan(&plan, 0.10);
    let arr = steady_arrivals(8.0, 90.0);
    let faults =
        FaultPlan::none().with(Fault::PoolDark { pool: 1, at_s: 20.0, until_s: Some(60.0) });
    let run = |cfg: &ResilienceConfig| -> SimOutcome {
        let mut p = StaticPolicy::new(1, "acc");
        simulate_topology_resilient(&arr, &plan, &mut p, &svc, 42, &topo, 1, &faults, cfg)
    };
    let on = run(&ResilienceConfig::enabled());
    let off = run(&ResilienceConfig::default());
    conservation("des pin on", on.records.len(), on.rejected, on.failed, arr.len());
    conservation("des pin off", off.records.len(), off.rejected, off.failed, arr.len());
    let g_on = slo_goodput(&on.records, arr.len(), plan.slo_ms);
    let g_off = slo_goodput(&off.records, arr.len(), plan.slo_ms);
    assert!(
        g_on > g_off,
        "resilience must strictly beat drain/pause in the DES: on {g_on:.3} vs off {g_off:.3}"
    );
}

#[test]
fn live_failover_goodput_strictly_beats_drain_under_dark_window() {
    let pools = parse_pools("fast:2:1.0,acc:2:1.0").unwrap();
    let n = 400;
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.003).collect();
    let faults = FaultPlan::none().with(Fault::PoolDark { pool: 1, at_s: 0.3, until_s: Some(0.9) });
    let run = |cfg: ResilienceConfig| {
        serve(
            move || Ok(SleepEngine { service_ms: 2.0 }),
            Box::new(StaticPolicy::new(1, "acc")),
            &arrivals,
            &ServeOptions {
                pools: pools.clone(),
                faults: faults.clone(),
                resilience: cfg,
                ..ServeOptions::default()
            },
        )
        .unwrap()
    };
    let on = run(ResilienceConfig::enabled());
    let off = run(ResilienceConfig::default());
    conservation("live pin on", on.records.len(), on.rejected, on.failed, n);
    conservation("live pin off", off.records.len(), off.rejected, off.failed, n);
    // 100 ms SLO: the 600 ms pause forces every in-window arrival on
    // the paused path far past it, while failover keeps them at ~2 ms
    // service on the surviving pool.
    let g_on = slo_goodput(&on.records, n, 100.0);
    let g_off = slo_goodput(&off.records, n, 100.0);
    assert!(
        g_on > g_off,
        "resilience must strictly beat drain/pause live: on {g_on:.3} vs off {g_off:.3}"
    );
    assert!(on.failovers >= 1);
}
