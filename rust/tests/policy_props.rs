//! Property tests (mini in-house framework — no proptest offline):
//! controller invariants over randomized plans and load traces.

use compass::planner::{derive_plan, AqmParams, LatencyProfile, ProfiledConfig};
use compass::serving::policy::ScalingPolicy;
use compass::serving::ElasticoPolicy;
use compass::util::Rng;

/// Generate a random valid Pareto ladder (2-6 rungs).
fn random_front(rng: &mut Rng) -> Vec<ProfiledConfig> {
    let n = 2 + rng.choice_index(5);
    let mut mean = 5.0 + rng.uniform() * 30.0;
    let mut acc = 0.5 + rng.uniform() * 0.2;
    (0..n)
        .map(|i| {
            mean *= 1.3 + rng.uniform() * 2.0;
            acc += 0.01 + rng.uniform() * 0.08;
            ProfiledConfig {
                config: vec![i],
                label: format!("rung{i}"),
                accuracy: acc.min(0.99),
                latency: LatencyProfile {
                    mean_ms: mean,
                    p50_ms: mean,
                    p95_ms: mean * (1.1 + rng.uniform() * 0.5),
                    runs: 10,
                },
            }
        })
        .collect()
}

#[test]
fn prop_plan_invariants() {
    let mut rng = Rng::new(41);
    for case in 0..300 {
        let front = random_front(&mut rng);
        let slo = front.last().unwrap().latency.p95_ms * (0.5 + rng.uniform() * 3.0);
        let plan = derive_plan(&front, AqmParams::for_slo(slo));
        // Non-empty, ordered, decreasing upscale thresholds (Eq. 11).
        assert!(!plan.ladder.is_empty(), "case {case}");
        for w in plan.ladder.windows(2) {
            assert!(w[0].mean_ms <= w[1].mean_ms, "case {case}: ladder order");
            assert!(
                w[0].upscale_threshold >= w[1].upscale_threshold,
                "case {case}: Eq. 11 violated"
            );
        }
        // Every retained rung (except a degraded-mode singleton) meets
        // the SLO with positive slack.
        if plan.ladder.len() > 1 {
            for p in &plan.ladder {
                assert!(p.queue_slack_ms > 0.0, "case {case}: negative slack");
            }
        }
        // Downscale threshold present on all but the last rung.
        for (i, p) in plan.ladder.iter().enumerate() {
            assert_eq!(
                p.downscale_threshold.is_some(),
                i + 1 < plan.ladder.len(),
                "case {case}: downscale structure"
            );
        }
    }
}

#[test]
fn prop_elastico_rung_always_valid_and_spikes_upscale() {
    let mut rng = Rng::new(43);
    for case in 0..200 {
        let front = random_front(&mut rng);
        let slo = front.last().unwrap().latency.p95_ms * (1.0 + rng.uniform() * 2.0);
        let plan = derive_plan(&front, AqmParams::for_slo(slo));
        let rungs = plan.ladder.len();
        let mut ela = ElasticoPolicy::new(plan);
        let mut t = 0.0;
        let mut prev = ela.current();
        for _ in 0..2000 {
            t += rng.uniform() * 50.0;
            let depth = (rng.uniform() * rng.uniform() * 40.0) as usize;
            let cur = ela.decide(t, depth);
            assert!(cur < rungs, "case {case}: rung out of range");
            // Single-step moves only.
            assert!(
                (cur as i64 - prev as i64).abs() <= 1,
                "case {case}: multi-rung jump"
            );
            prev = cur;
        }
        // A sustained massive spike must drive it to the fastest rung.
        for _ in 0..50 {
            t += 10.0;
            ela.decide(t, 10_000);
        }
        assert_eq!(ela.current(), 0, "case {case}: spike must reach fastest");
    }
}

#[test]
fn prop_no_downscale_before_cooldown() {
    let mut rng = Rng::new(47);
    for case in 0..100 {
        let front = random_front(&mut rng);
        let slo = front.last().unwrap().latency.p95_ms * 2.0;
        let plan = derive_plan(&front, AqmParams::for_slo(slo));
        let cooldown = plan.down_cooldown_ms;
        let mut ela = ElasticoPolicy::new(plan);
        // Drive to fastest.
        let mut t = 0.0;
        for _ in 0..20 {
            t += 1.0;
            ela.decide(t, 10_000);
        }
        let base = ela.current();
        // Idle observations strictly inside the cooldown window.
        let t0 = t;
        while t - t0 < cooldown * 0.95 {
            t += cooldown / 50.0;
            let cur = ela.decide(t, 0);
            assert!(
                cur <= base + 0 || cur == base,
                "case {case}: downscaled before cooldown"
            );
            assert_eq!(cur, base, "case {case}: downscaled at {}ms", t - t0);
        }
    }
}

#[test]
fn prop_sim_conservation_and_fifo() {
    // Simulator invariants under random workloads and policies.
    use compass::experiments::common::{make_policy, simulate_boxed};
    use compass::sim::LognormalService;
    use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

    let mut rng = Rng::new(53);
    for case in 0..30 {
        let front = random_front(&mut rng);
        let slo = front.last().unwrap().latency.p95_ms * 2.0;
        let plan = derive_plan(&front, AqmParams::for_slo(slo));
        let arrivals = generate_arrivals(&WorkloadSpec {
            base_qps: 0.3 / (plan.ladder.last().unwrap().mean_ms / 1000.0),
            duration_s: 30.0,
            pattern: if case % 2 == 0 {
                Pattern::paper_spike()
            } else {
                Pattern::paper_bursty()
            },
            seed: case,
        });
        let svc = LognormalService::from_plan(&plan, 0.2);
        for name in ["Elastico", "Static-Fast"] {
            let mut policy = make_policy(&plan, name);
            let out = simulate_boxed(&arrivals, &plan, &mut policy, &svc, case);
            // Conservation: every arrival served exactly once.
            assert_eq!(out.records.len(), arrivals.len(), "case {case}");
            let mut ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
            ids.sort();
            ids.dedup();
            assert_eq!(ids.len(), arrivals.len(), "case {case}: dup/missing ids");
            // Causality + single server.
            let mut by_start = out.records.clone();
            by_start.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
            for w in by_start.windows(2) {
                assert!(w[1].start_ms >= w[0].finish_ms - 1e-6, "case {case}: overlap");
            }
            for r in &out.records {
                assert!(r.start_ms >= r.arrival_ms - 1e-9, "case {case}: time travel");
                assert!(r.finish_ms > r.start_ms, "case {case}: zero service");
            }
        }
    }
}
