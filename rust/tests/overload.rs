//! Integration: the overload plane — SLO classes with per-request
//! deadlines, deadline-aware admission and shedding, lazy in-queue
//! expiry, and brownout degradation — in BOTH executors, with the
//! extended conservation law
//! `served + rejected + failed + shed + expired == arrivals`
//! holding everywhere, including under chaos.
//!
//! Two pins anchor the PR:
//!
//! 1. **Disabled parity** — `OverloadConfig::default()` (off)
//!    reproduces the plain DES engine bit for bit, and the live server
//!    with the plane off reports all-zero overload counters.
//! 2. **Deadline-aware beats tail-drop** — under the same sustained
//!    overload, same arrivals and same seed, deadline-aware shedding
//!    yields strictly higher gold-class compliance (per *offered* gold
//!    arrival) than the tail-drop twin, in both the DES and the live
//!    runtime.

use std::time::Duration;

use anyhow::Result;
use compass::planner::{derive_plan, AqmParams, LatencyProfile, Plan, ProfiledConfig};
use compass::serving::executor::RequestEngine;
use compass::serving::{
    parse_classes, parse_pools, serve, OverloadConfig, ResilienceConfig, ServeOptions,
    StaticPolicy, Topology,
};
use compass::sim::{simulate_topology, simulate_topology_overload, LognormalService, SimOutcome};
use compass::workflows::ExecOutcome;
use compass::workload::{Fault, FaultPlan};

/// Synthetic two-rung plan (fast 20 ms, accurate 90 ms), same idiom as
/// the resilience suite.
fn plan2() -> Plan {
    let mk = |label: &str, acc: f64, mean: f64, p95: f64| ProfiledConfig {
        config: vec![],
        label: label.into(),
        accuracy: acc,
        latency: LatencyProfile { mean_ms: mean, p50_ms: mean, p95_ms: p95, runs: 10 },
    };
    derive_plan(
        &[mk("fast", 0.76, 20.0, 28.0), mk("accurate", 0.85, 90.0, 120.0)],
        AqmParams::for_slo(300.0),
    )
}

fn steady_arrivals(qps: f64, dur: f64) -> Vec<f64> {
    let n = (qps * dur) as usize;
    (0..n).map(|i| i as f64 / qps).collect()
}

/// The extended conservation law: every arrival ends in exactly one of
/// served / rejected / failed / shed / expired.
fn conserve5(
    label: &str,
    served: usize,
    rejected: usize,
    failed: usize,
    shed: usize,
    expired: usize,
    arrivals: usize,
) {
    assert_eq!(
        served + rejected + failed + shed + expired,
        arrivals,
        "{label}: {served} served + {rejected} rejected + {failed} failed + {shed} shed \
         + {expired} expired != {arrivals} arrivals"
    );
}

/// Sleeps out a fixed service time, always succeeds.
struct SleepEngine {
    service_ms: f64,
}

impl RequestEngine for SleepEngine {
    fn execute(&mut self, _idx: usize) -> Result<ExecOutcome> {
        std::thread::sleep(Duration::from_secs_f64(self.service_ms / 1e3));
        Ok(ExecOutcome { accuracy: 0.8, success: None })
    }

    fn rungs(&self) -> usize {
        2
    }
}

// ---------------------------------------------------------------------
// Pin 1: the plane off is invisible in both executors
// ---------------------------------------------------------------------

#[test]
fn des_disabled_overload_is_bit_identical_to_the_plain_engine() {
    let plan = plan2();
    let arr = steady_arrivals(12.0, 60.0);
    let svc = LognormalService::from_plan(&plan, 0.25);
    let topo = Topology::uniform(2, 2);
    let mut p1 = compass::serving::ElasticoPolicy::new(plan.clone());
    let base = simulate_topology(&arr, &plan, &mut p1, &svc, 42, &topo, 1);
    let mut p2 = compass::serving::ElasticoPolicy::new(plan.clone());
    let out = simulate_topology_overload(
        &arr,
        &plan,
        &mut p2,
        &svc,
        42,
        &topo,
        1,
        &FaultPlan::none(),
        &ResilienceConfig::default(),
        &OverloadConfig::default(),
    );
    assert_eq!(base.records.len(), out.records.len());
    for (x, y) in base.records.iter().zip(&out.records) {
        assert_eq!(x, y, "disabled overload must not perturb the DES");
    }
    assert_eq!(base.switches.len(), out.switches.len());
    assert_eq!((out.shed, out.expired, out.brownout_steps), (0, 0, 0));
}

#[test]
fn live_overload_off_reports_zero_counters() {
    let n = 60;
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.003).collect();
    let out = serve(
        move || Ok(SleepEngine { service_ms: 1.0 }),
        Box::new(StaticPolicy::new(0, "fast")),
        &arrivals,
        &ServeOptions { workers: 2, ..ServeOptions::default() },
    )
    .unwrap();
    conserve5("live off", out.records.len(), out.rejected, out.failed, out.shed, out.expired, n);
    assert_eq!((out.shed, out.expired, out.brownout_steps), (0, 0, 0));
}

// ---------------------------------------------------------------------
// DES: shedding, expiry, brownout under sustained overload
// ---------------------------------------------------------------------

/// 1.5x capacity on a 2-worker, 20 ms rung: 150 qps against 100 qps.
fn overload_run(cfg: &OverloadConfig) -> (SimOutcome, Vec<f64>) {
    let plan = plan2();
    let arr = steady_arrivals(150.0, 20.0);
    let svc = LognormalService::from_plan(&plan, 0.10);
    let topo = Topology::uniform(2, 2);
    let mut p = StaticPolicy::new(0, "fast");
    let out = simulate_topology_overload(
        &arr,
        &plan,
        &mut p,
        &svc,
        42,
        &topo,
        1,
        &FaultPlan::none(),
        &ResilienceConfig::default(),
        cfg,
    );
    (out, arr)
}

#[test]
fn des_deadline_shedding_strictly_beats_tail_drop_on_gold_compliance() {
    let plan = plan2();
    let aware_cfg = OverloadConfig::enabled();
    let tail_cfg = OverloadConfig::tail_drop();
    let (aware, arr) = overload_run(&aware_cfg);
    let (tail, _) = overload_run(&tail_cfg);
    conserve5(
        "des aware",
        aware.records.len(),
        aware.rejected,
        aware.failed,
        aware.shed,
        aware.expired,
        arr.len(),
    );
    conserve5(
        "des tail",
        tail.records.len(),
        tail.rejected,
        tail.failed,
        tail.shed,
        tail.expired,
        arr.len(),
    );
    assert!(aware.shed > 0, "1.5x sustained load must engage the admission gate");
    let g_aware = aware_cfg.class_compliance(&aware.records, arr.len(), plan.slo_ms)[0];
    let g_tail = tail_cfg.class_compliance(&tail.records, arr.len(), plan.slo_ms)[0];
    assert!(
        g_aware > g_tail,
        "deadline-aware shedding must strictly beat tail-drop on gold compliance \
         in the DES: aware {g_aware:.3} vs tail {g_tail:.3}"
    );
}

#[test]
fn des_lazy_expiry_skips_doomed_requests_and_conserves() {
    // A uselessly deep tail-drop bound: nothing is shed, the backlog
    // grows without limit, and queued gold/silver requests blow their
    // deadlines long before a worker reaches them — the lazy expiry
    // path must skip (and count) them instead of serving stale work.
    let cfg = OverloadConfig { shed_depth: 10_000, ..OverloadConfig::tail_drop() };
    let (out, arr) = overload_run(&cfg);
    conserve5(
        "des expiry",
        out.records.len(),
        out.rejected,
        out.failed,
        out.shed,
        out.expired,
        arr.len(),
    );
    assert_eq!(out.shed, 0, "the gate never engages below shed_depth");
    assert!(out.expired > 0, "deep backlogs must expire finite-deadline requests in queue");
    assert!(
        out.brownout_steps >= 1,
        "sustained deadline pressure must step the brownout at least once"
    );
}

#[test]
fn des_overload_replays_bit_identically() {
    let (a, _) = overload_run(&OverloadConfig::enabled());
    let (b, _) = overload_run(&OverloadConfig::enabled());
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x, y, "the overloaded DES must replay bit-identically");
    }
    assert_eq!((a.shed, a.expired, a.brownout_steps), (b.shed, b.expired, b.brownout_steps));
}

#[test]
fn des_conservation_holds_under_overload_plus_chaos() {
    // Overload on top of the PR-7 chaos drills: a windowed dark pool
    // AND a flaky engine window, with resilience (retries + failover)
    // and deadline-aware shedding active at once. Every arrival must
    // still land in exactly one terminal bucket.
    let pools = parse_pools("fast:2:1.0,acc:2:1.0").unwrap();
    let topo = Topology::from_pools(&pools, 0.0).unwrap();
    let plan = plan2();
    let svc = LognormalService::from_plan(&plan, 0.10);
    let arr = steady_arrivals(220.0, 20.0);
    let faults = FaultPlan::none()
        .with(Fault::PoolDark { pool: 1, at_s: 5.0, until_s: Some(12.0) })
        .with(Fault::EngineFlaky { pool: 0, rate: 0.25, from_s: 8.0, to_s: 15.0 });
    let run = || -> SimOutcome {
        let mut p = StaticPolicy::new(0, "fast");
        simulate_topology_overload(
            &arr,
            &plan,
            &mut p,
            &svc,
            42,
            &topo,
            1,
            &faults,
            &ResilienceConfig::enabled(),
            &OverloadConfig::enabled(),
        )
    };
    let out = run();
    conserve5(
        "des chaos",
        out.records.len(),
        out.rejected,
        out.failed,
        out.shed,
        out.expired,
        arr.len(),
    );
    assert!(out.shed > 0, "overload past capacity must shed");
    // Chaos + overload together stay deterministic.
    let again = run();
    assert_eq!(out.records.len(), again.records.len());
    for (x, y) in out.records.iter().zip(&again.records) {
        assert_eq!(x, y, "chaos + overload DES must replay bit-identically");
    }
}

// ---------------------------------------------------------------------
// Live executor: the strict-beat pin and expiry under real threads
// ---------------------------------------------------------------------

#[test]
fn live_deadline_shedding_strictly_beats_tail_drop_on_gold_compliance() {
    // 2 workers x 4 ms service = ~500 qps capacity; arrivals every
    // 1.8 ms = ~555 qps offered. Deadlines are scaled to the 4 ms rung
    // (gold 80 ms => a 40-deep gold budget) so the admission gate
    // engages well below the 256-deep tail-drop bound.
    let n = 1500;
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.0018).collect();
    let classes = parse_classes("gold:0.2:80,silver:0.5:400,bronze:0.3:0").unwrap();
    let run = |cfg: OverloadConfig| {
        let cfg = cfg.with_classes(classes.clone()).with_rung_means(vec![4.0, 4.0]);
        let out = serve(
            move || Ok(SleepEngine { service_ms: 4.0 }),
            Box::new(StaticPolicy::new(0, "fast")),
            &arrivals,
            &ServeOptions { workers: 2, overload: cfg.clone(), ..ServeOptions::default() },
        )
        .unwrap();
        (out, cfg)
    };
    let (aware, aware_cfg) = run(OverloadConfig::enabled());
    let (tail, tail_cfg) = run(OverloadConfig::tail_drop());
    conserve5(
        "live aware",
        aware.records.len(),
        aware.rejected,
        aware.failed,
        aware.shed,
        aware.expired,
        n,
    );
    conserve5(
        "live tail",
        tail.records.len(),
        tail.rejected,
        tail.failed,
        tail.shed,
        tail.expired,
        n,
    );
    assert!(aware.shed > 0, "sustained 1.1x load must engage the admission gate");
    let g_aware = aware_cfg.class_compliance(&aware.records, n, 300.0)[0];
    let g_tail = tail_cfg.class_compliance(&tail.records, n, 300.0)[0];
    assert!(
        g_aware > g_tail,
        "deadline-aware shedding must strictly beat tail-drop on gold compliance \
         live: aware {g_aware:.3} vs tail {g_tail:.3}"
    );
}
