//! Integration: the scenario matrix subsystem — generator statistics,
//! deterministic replay, fault conservation in both executors, and the
//! sweep harness's JSON artifact.
//!
//! The acceptance pin of the subsystem lives here: the same
//! [`ScenarioSpec`] (spec + seed) produces bit-identical arrivals on
//! every call, and that one vector drives the live `serve()` executor
//! and the DES `simulate_topology` with every request accounted for in
//! both worlds (`served + rejected + failed == arrivals`).

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;
use compass::experiments::scenarios::{
    faults_for, run_sweep, ScenarioOpts, SCENARIOS, SCHEMA, SMOKE_SCENARIOS, SMOKE_TOPOLOGIES,
    TOPOLOGIES,
};
use compass::experiments::ExperimentCtx;
use compass::planner::{derive_plan, AqmParams, LatencyProfile, Plan, ProfiledConfig};
use compass::serving::executor::RequestEngine;
use compass::serving::{parse_pools, serve, Discipline, ServeOptions, StaticPolicy, Topology};
use compass::sim::{simulate_topology, simulate_topology_faults, LognormalService};
use compass::util::json::Json;
use compass::workflows::ExecOutcome;
use compass::workload::trace::{load_request_log, load_trace};
use compass::workload::{empirical_qps, Fault, FaultPlan, Generator, ScenarioSpec};

/// Synthetic two-rung plan (fast 20 ms, accurate 90 ms) — no offline
/// search needed, same idiom as the engine parity suite.
fn plan2() -> Plan {
    let mk = |label: &str, acc: f64, mean: f64, p95: f64| ProfiledConfig {
        config: vec![],
        label: label.into(),
        accuracy: acc,
        latency: LatencyProfile { mean_ms: mean, p50_ms: mean, p95_ms: p95, runs: 10 },
    };
    derive_plan(
        &[mk("fast", 0.76, 20.0, 28.0), mk("accurate", 0.85, 90.0, 120.0)],
        AqmParams::for_slo(300.0),
    )
}

fn steady_arrivals(qps: f64, dur: f64, seed: u64) -> Vec<f64> {
    ScenarioSpec { generator: Generator::Constant { qps }, duration_s: dur, seed }.arrivals()
}

#[test]
fn diurnal_mean_rate_matches_base() {
    // Whole sinusoid periods integrate to the base rate.
    let spec = ScenarioSpec {
        generator: Generator::Diurnal { qps: 6.0, amplitude: 0.6, period_s: 150.0, phase_s: 0.0 },
        duration_s: 600.0,
        seed: 13,
    };
    let qps = empirical_qps(&spec.arrivals(), 600.0);
    assert!((qps - 6.0).abs() < 0.5, "diurnal mean qps {qps} vs base 6.0");
}

#[test]
fn scenario_arrivals_replay_bit_identically() {
    let spec = ScenarioSpec {
        generator: Generator::Mmpp { qps: vec![2.0, 14.0], mean_dwell_s: vec![12.0, 4.0] },
        duration_s: 120.0,
        seed: 9,
    };
    let a = spec.arrivals();
    let b = spec.arrivals();
    assert_eq!(a.len(), b.len());
    assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    let mut other = spec.clone();
    other.seed = 10;
    assert_ne!(a, other.arrivals(), "different seeds must decorrelate");
}

#[test]
fn empty_fault_plan_reproduces_the_engine_bit_for_bit() {
    let plan = plan2();
    let arr = steady_arrivals(12.0, 60.0, 5);
    let svc = LognormalService::from_plan(&plan, 0.25);
    let topo = Topology::uniform(2, 2);
    let mut p1 = compass::serving::ElasticoPolicy::new(plan.clone());
    let base = simulate_topology(&arr, &plan, &mut p1, &svc, 42, &topo, 1);
    let mut p2 = compass::serving::ElasticoPolicy::new(plan.clone());
    let none = FaultPlan::none();
    let faulted = simulate_topology_faults(&arr, &plan, &mut p2, &svc, 42, &topo, 1, &none);
    assert_eq!(faulted.rejected, 0);
    assert_eq!(base.records.len(), faulted.records.len());
    for (x, y) in base.records.iter().zip(&faulted.records) {
        assert_eq!(x, y, "empty FaultPlan must not perturb the engine");
    }
    assert_eq!(base.switches.len(), faulted.switches.len());
    assert_eq!(base.steals, faulted.steals);
    assert_eq!(base.spills, faulted.spills);
}

#[test]
fn des_pool_dark_conserves_and_spills() {
    let pools = parse_pools("fast:2:1.0,acc:2:2.0").unwrap();
    let topo = Topology::from_pools(&pools, 0.0).unwrap();
    let plan = plan2();
    let svc = LognormalService::from_plan(&plan, 0.10);
    let arr = steady_arrivals(8.0, 60.0, 5);
    let faults = FaultPlan::none().with(Fault::PoolDark { pool: 1, at_s: 20.0, until_s: None });
    // Static-Accurate routes everything to the (soon dark) slow pool.
    let mut p = StaticPolicy::new(1, "acc");
    let out = simulate_topology_faults(&arr, &plan, &mut p, &svc, 42, &topo, 1, &faults);
    assert_eq!(
        out.records.len() + out.rejected,
        arr.len(),
        "pool-dark run must account for every arrival"
    );
    assert!(out.spills >= 1, "alive pool never absorbed the dark pool's backlog");
    assert!(!out.records.is_empty());
    // Fault-free control: nothing rejected.
    let mut p0 = StaticPolicy::new(1, "acc");
    let none = FaultPlan::none();
    let ok = simulate_topology_faults(&arr, &plan, &mut p0, &svc, 42, &topo, 1, &none);
    assert_eq!(ok.rejected, 0);
    assert_eq!(ok.records.len(), arr.len());
}

#[test]
fn des_queue_squeeze_conserves_and_rejects() {
    let plan = plan2();
    let svc = LognormalService::from_plan(&plan, 0.10);
    let topo = Topology::uniform(1, 1);
    // Overload the 90 ms rung (ρ ≈ 1.1) so the squeezed bound bites.
    let arr = steady_arrivals(12.0, 60.0, 5);
    let faults =
        FaultPlan::none().with(Fault::QueueSqueeze { capacity: 2, from_s: 10.0, to_s: 50.0 });
    let mut p = StaticPolicy::new(1, "acc");
    let out = simulate_topology_faults(&arr, &plan, &mut p, &svc, 42, &topo, 1, &faults);
    assert!(out.rejected > 0, "squeeze to depth 2 under overload must reject");
    assert_eq!(out.records.len() + out.rejected, arr.len());
    let mut p0 = StaticPolicy::new(1, "acc");
    let none = FaultPlan::none();
    let ok = simulate_topology_faults(&arr, &plan, &mut p0, &svc, 42, &topo, 1, &none);
    assert_eq!(ok.rejected, 0);
}

/// Scripted engine that sleeps out its service time.
struct SleepEngine {
    service_ms: f64,
}

impl RequestEngine for SleepEngine {
    fn execute(&mut self, _idx: usize) -> Result<ExecOutcome> {
        std::thread::sleep(Duration::from_secs_f64(self.service_ms / 1e3));
        Ok(ExecOutcome { accuracy: 0.8, success: None })
    }

    fn rungs(&self) -> usize {
        2
    }
}

#[test]
fn scenario_arrivals_drive_live_and_des_identically() {
    // The acceptance pin: one ScenarioSpec, two executors. The spec's
    // arrivals are bit-identical across calls, and both the live server
    // and the DES consume that exact vector — every request id shows up
    // (served or rejected) in both worlds.
    let spec = ScenarioSpec {
        generator: Generator::FlashCrowd {
            qps: 30.0,
            peak_factor: 3.0,
            at_s: 0.8,
            ramp_s: 0.2,
            hold_s: 0.4,
        },
        duration_s: 2.0,
        seed: 42,
    };
    let arr = spec.arrivals();
    let again = spec.arrivals();
    assert!(arr.iter().zip(&again).all(|(x, y)| x.to_bits() == y.to_bits()));
    assert!(!arr.is_empty());

    // DES side: record arrivals are the input times (ms), bit for bit.
    let plan = plan2();
    let svc = LognormalService::from_plan(&plan, 0.10);
    let topo = Topology::uniform(2, 2);
    let mut p = StaticPolicy::new(0, "fast");
    let sim = simulate_topology(&arr, &plan, &mut p, &svc, 42, &topo, 1);
    assert_eq!(sim.records.len(), arr.len());
    let mut sim_records = sim.records.clone();
    sim_records.sort_by_key(|r| r.id);
    for (r, t) in sim_records.iter().zip(&arr) {
        assert_eq!(r.arrival_ms.to_bits(), (t * 1e3).to_bits());
    }

    // Live side: same vector, every arrival accounted for.
    let out = serve(
        move || Ok(SleepEngine { service_ms: 1.0 }),
        Box::new(StaticPolicy::new(0, "fast")),
        &arr,
        &ServeOptions {
            workers: 2,
            discipline: Discipline::ShardedSteal,
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(out.records.len() + out.rejected, arr.len());
    let mut ids: Vec<u64> = out.records.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), out.records.len(), "live run duplicated a request id");
}

#[test]
fn live_pool_dark_conserves_every_arrival() {
    // Two pools; the accurate pool goes dark mid-run. The fast pool's
    // spill-when-dry absorbs what it can while the queue is open; the
    // dark pool's drain counts the rest as rejected — either way
    // served + rejected == arrivals.
    let pools = parse_pools("fast:2:1.0,acc:2:1.0").unwrap();
    let n = 150;
    let arrivals: Vec<f64> = (0..n).map(|i| i as f64 * 0.003).collect();
    let out = serve(
        move || Ok(SleepEngine { service_ms: 2.0 }),
        Box::new(StaticPolicy::new(1, "acc")),
        &arrivals,
        &ServeOptions {
            pools: pools.clone(),
            faults: FaultPlan::none().with(Fault::PoolDark { pool: 1, at_s: 0.2, until_s: None }),
            ..ServeOptions::default()
        },
    )
    .unwrap();
    assert_eq!(
        out.records.len() + out.rejected,
        n,
        "live pool-dark run must account for every arrival"
    );
    // Post-dark arrivals either spill to the alive pool or get rejected
    // by the dark pool's drain — which of the two wins is timing, but
    // one of them must have fired.
    assert!(out.spills >= 1 || out.rejected >= 1, "dark pool kept serving its whole load");
}

#[test]
fn sweep_writes_schema_valid_json() {
    let out_dir = std::env::temp_dir().join("compass_scenarios_test");
    let out = out_dir.join("BENCH_scenarios.json");
    let ctx = ExperimentCtx {
        duration_s: 8.0,
        seed: 5,
        out_dir: out_dir.clone(),
        ..ExperimentCtx::default()
    };
    let opts = ScenarioOpts {
        scenarios: vec!["steady".into(), "pool_dark".into(), "overload_sustained".into()],
        topos: vec!["pooled-2x2".into()],
        policies: vec!["Static-Accurate".into()],
        out: out.clone(),
        ..ScenarioOpts::default()
    };
    run_sweep(&ctx, &opts).unwrap();
    let doc = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    assert_eq!(doc.get("schema").unwrap().as_str(), Some(SCHEMA));
    let cells = doc.get("cells").unwrap().as_obj().unwrap();
    assert_eq!(cells.len(), 3);
    for (key, cell) in cells {
        let f = |k: &str| cell.get(k).unwrap().as_f64().unwrap();
        assert_eq!(
            f("served") + f("rejected") + f("failed") + f("shed") + f("expired"),
            f("arrivals"),
            "conservation violated in {key}"
        );
        let comp = f("slo_compliance");
        assert!((0.0..=1.0).contains(&comp), "{key}: compliance {comp}");
        let goodput = f("slo_goodput");
        assert!((0.0..=1.0).contains(&goodput), "{key}: slo_goodput {goodput}");
        let gold = f("gold_compliance");
        assert!((0.0..=1.0).contains(&gold), "{key}: gold_compliance {gold}");
        assert!(cell.get("resilience").unwrap().as_str().is_some(), "{key}: resilience tag");
        assert!(cell.get("overload").unwrap().as_str().is_some(), "{key}: overload tag");
        assert!(f("p50_ms") <= f("p95_ms") && f("p95_ms") <= f("p99_ms"), "{key}");
    }
    let dark = &cells["pool_dark|pooled-2x2|Static-Accurate"];
    assert_ne!(dark.get("faults").unwrap().as_str(), Some("none"));
    assert!(dark.get("spills").unwrap().as_f64().unwrap() >= 1.0);
    let over = &cells["overload_sustained|pooled-2x2|Static-Accurate"];
    assert_eq!(over.get("overload").unwrap().as_str(), Some("deadline"));
    let steady = &cells["steady|pooled-2x2|Static-Accurate"];
    assert_eq!(steady.get("overload").unwrap().as_str(), Some("off"));
    let _ = std::fs::remove_dir_all(&out_dir);
}

#[test]
fn smoke_matrix_is_a_subset_and_meets_the_floor() {
    assert!(SMOKE_SCENARIOS.iter().all(|s| SCENARIOS.contains(s)));
    assert!(SMOKE_TOPOLOGIES.iter().all(|t| TOPOLOGIES.contains(t)));
    // The acceptance floor: ≥ 5 scenario shapes × ≥ 2 topologies even
    // in the reduced CI matrix.
    assert!(SMOKE_SCENARIOS.len() >= 5);
    assert!(SMOKE_TOPOLOGIES.len() >= 2);
    // Every smoke fault path is exercised: pool_dark needs the second
    // pool of pooled-2x2, squeeze and slowdown apply everywhere.
    assert!(!faults_for("pool_dark", 30.0, 2).is_empty());
    assert!(!faults_for("squeeze", 30.0, 1).is_empty());
    // Chaos cells: the windowed dark pair and the flaky engine window.
    assert!(!faults_for("dark_recover", 30.0, 2).is_empty());
    assert!(!faults_for("dark_drain", 30.0, 2).is_empty());
    assert!(!faults_for("flaky", 30.0, 1).is_empty());
}

#[test]
fn cookbook_fixture_traces_load() {
    let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/fixtures"));
    for name in SCENARIOS {
        let arr = load_trace(&dir.join(format!("{name}.csv"))).unwrap();
        assert!(!arr.is_empty(), "fixture {name} is empty");
        assert!(arr.windows(2).all(|w| w[0] <= w[1]), "fixture {name} unsorted");
    }
    let log = load_request_log(&dir.join("pool_dark_log.csv")).unwrap();
    assert!(!log.is_empty());
    for row in &log {
        assert!(row.finish_ms >= row.start_ms && row.start_ms >= row.arrival_ms);
        assert!(["ok", "fail", "na"].contains(&row.outcome.as_str()));
    }
}
