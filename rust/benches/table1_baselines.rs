//! Bench: the offline planning phase (paper Table I derivation): search +
//! modeled profiling + Pareto + AQM.
use compass::experiments::common::offline_phase;
use compass::util::bench::{bench, group};

fn main() {
    group("table1: offline planning phase (modeled)");
    bench("offline_phase tau=0.75", 1, 10, || {
        let (_s, plan) = offline_phase(0.75, 1000.0, 7, false).unwrap();
        std::hint::black_box(plan.ladder.len());
    });
}
