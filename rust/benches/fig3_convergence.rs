//! Bench: COMPASS-V convergence (paper Fig. 3) — times the search at
//! representative thresholds and regenerates the anytime curve.
use compass::configspace::rag_space;
use compass::oracle::RagOracle;
use compass::search::{CompassV, CompassVParams};
use compass::util::bench::{bench, group};

fn main() {
    group("fig3: COMPASS-V search (RAG space)");
    let space = rag_space();
    for tau in [0.50, 0.75, 0.85] {
        bench(&format!("compass_v tau={tau}"), 1, 10, || {
            let mut oracle = RagOracle::new_rag(7);
            let r = CompassV::new(CompassVParams { seed: 7, ..Default::default() })
                .run(&space, tau, &mut oracle);
            std::hint::black_box(r.feasible.len());
        });
    }
}
