//! Bench: the full Fig. 4 sweep (both workflows, 16 thresholds) — the
//! offline-phase cost COMPASS-V saves vs exhaustive search.
use compass::configspace::{detection_space, rag_space};
use compass::oracle::{DetectionOracle, RagOracle};
use compass::search::{grid_search, BudgetSchedule, CompassV, CompassVParams};
use compass::util::bench::{bench, group};

fn main() {
    group("fig4: search vs exhaustive (sample efficiency)");
    let rag = rag_space();
    bench("compass_v rag 8-tau sweep", 1, 5, || {
        for tau in [0.30, 0.40, 0.50, 0.60, 0.70, 0.75, 0.80, 0.85] {
            let mut o = RagOracle::new_rag(7);
            let r = CompassV::new(CompassVParams { seed: 7, ..Default::default() })
                .run(&rag, tau, &mut o);
            std::hint::black_box(r.samples_used);
        }
    });
    bench("grid_search rag (exhaustive baseline)", 1, 5, || {
        let mut o = RagOracle::new_rag(7);
        std::hint::black_box(grid_search(&rag, 100, &mut o).samples_used);
    });
    let det = detection_space();
    bench("compass_v detection tau=0.70", 1, 5, || {
        let mut o = DetectionOracle::new_detection(7);
        let r = CompassV::new(CompassVParams {
            seed: 7,
            schedule: BudgetSchedule::detection(),
            ..Default::default()
        })
        .run(&det, 0.70, &mut o);
        std::hint::black_box(r.samples_used);
    });
}
