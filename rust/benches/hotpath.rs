//! Bench: L3 coordinator hot-path microbenchmarks (perf pass §Perf):
//! queue ops, monitor ticks, policy decisions, record aggregation —
//! everything on the request path *except* the model compute.
use compass::experiments::common::{make_policy, offline_phase};
use compass::metrics::{RequestRecord, RunSummary};
use compass::serving::monitor::LoadMonitor;
use compass::serving::RequestQueue;
use compass::util::bench::{bench, group};
use compass::util::Rng;

fn main() {
    group("hotpath: L3 coordinator overhead");

    bench("queue push+pop x1k", 2, 100, || {
        let q: RequestQueue<(u64, f64)> = RequestQueue::new(4096);
        for i in 0..1000u64 {
            q.push((i, i as f64)).unwrap();
        }
        for _ in 0..1000 {
            std::hint::black_box(
                q.pop_timeout(std::time::Duration::from_millis(1)).unwrap(),
            );
        }
    });

    bench("monitor tick x1k", 2, 100, || {
        let m = LoadMonitor::new(0.3);
        for i in 0..1000 {
            m.on_arrival();
            std::hint::black_box(m.tick(i as f64 * 10.0));
        }
    });

    let (_s, plan) = offline_phase(0.75, 1000.0, 7, false).unwrap();
    let mut policy = make_policy(&plan, "Elastico");
    bench("policy decide x1k", 2, 100, || {
        for i in 0..1000u64 {
            std::hint::black_box(policy.decide(i as f64, (i % 13) as usize));
        }
    });

    // Metrics aggregation over a large run.
    let mut rng = Rng::new(3);
    let records: Vec<RequestRecord> = (0..100_000)
        .map(|i| {
            let arr = i as f64;
            RequestRecord {
                id: i,
                arrival_ms: arr,
                start_ms: arr + rng.uniform() * 5.0,
                finish_ms: arr + 5.0 + rng.uniform() * 100.0,
                config_idx: (i % 3) as usize,
                accuracy: 0.8,
                success: None,
            }
        })
        .collect();
    bench("RunSummary::compute 100k records", 1, 20, || {
        std::hint::black_box(RunSummary::compute(&records, &[], 100.0, 3));
    });
}
