//! Bench: L3 coordinator hot-path microbenchmarks (perf pass §Perf):
//! queue ops, monitor ticks, policy decisions, record aggregation —
//! everything on the request path *except* the model compute — plus the
//! M/G/k simulator swept over the worker-pool sizes k ∈ {1, 2, 4, 8}.
use compass::experiments::common::{
    base_qps_k, make_policy, offline_phase, simulate_boxed_k,
};
use compass::metrics::{RequestRecord, RunSummary};
use compass::planner::{derive_plan, AqmParams, LatencyProfile, ProfiledConfig};
use compass::serving::monitor::LoadMonitor;
use compass::serving::RequestQueue;
use compass::sim::LognormalService;
use compass::util::bench::{bench, group};
use compass::util::Rng;
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

fn main() {
    group("hotpath: L3 coordinator overhead");

    bench("queue push+pop x1k", 2, 100, || {
        let q: RequestQueue<(u64, f64)> = RequestQueue::new(4096);
        for i in 0..1000u64 {
            q.push((i, i as f64)).unwrap();
        }
        for _ in 0..1000 {
            std::hint::black_box(
                q.pop_timeout(std::time::Duration::from_millis(1)).unwrap(),
            );
        }
    });

    bench("monitor tick x1k", 2, 100, || {
        let m = LoadMonitor::new(0.3);
        for i in 0..1000 {
            m.on_arrival();
            std::hint::black_box(m.tick(i as f64 * 10.0));
        }
    });

    let (_s, plan) = offline_phase(0.75, 1000.0, 7, false).unwrap();
    let mut policy = make_policy(&plan, "Elastico");
    bench("policy decide x1k", 2, 100, || {
        for i in 0..1000u64 {
            std::hint::black_box(policy.decide(i as f64, (i % 13) as usize));
        }
    });

    // Metrics aggregation over a large run.
    let mut rng = Rng::new(3);
    let records: Vec<RequestRecord> = (0..100_000)
        .map(|i| {
            let arr = i as f64;
            RequestRecord {
                id: i,
                arrival_ms: arr,
                start_ms: arr + rng.uniform() * 5.0,
                finish_ms: arr + 5.0 + rng.uniform() * 100.0,
                config_idx: (i % 3) as usize,
                accuracy: 0.8,
                success: None,
            }
        })
        .collect();
    bench("RunSummary::compute 100k records", 1, 20, || {
        std::hint::black_box(RunSummary::compute(&records, &[], 100.0, 3));
    });

    // M/G/k coordinator sweep: the paper's spike trace replayed through
    // the discrete-event simulator at each pool size, with worker-aware
    // thresholds and pool-scaled load (per-worker ρ held constant). The
    // ladder itself is k-independent, so the search/profiling above is
    // not repeated: per-k plans re-derive thresholds from its profile.
    group("hotpath: M/G/k simulator sweep");
    let front: Vec<ProfiledConfig> = plan
        .ladder
        .iter()
        .map(|p| ProfiledConfig {
            config: p.config.clone(),
            label: p.label.clone(),
            accuracy: p.accuracy,
            latency: LatencyProfile {
                mean_ms: p.mean_ms,
                p50_ms: p.mean_ms,
                p95_ms: p.p95_ms,
                runs: 0,
            },
        })
        .collect();
    for k in [1usize, 2, 4, 8] {
        let plan_k = derive_plan(&front, AqmParams::for_slo_workers(1000.0, k));
        let arrivals = generate_arrivals(&WorkloadSpec {
            base_qps: base_qps_k(&plan_k, k),
            duration_s: 180.0,
            pattern: Pattern::paper_spike(),
            seed: 7,
        });
        let svc = LognormalService::from_plan(&plan_k, 0.10);
        bench(&format!("simulate spike 180s k={k}"), 1, 20, || {
            let mut policy = make_policy(&plan_k, "Elastico");
            std::hint::black_box(simulate_boxed_k(
                &arrivals, &plan_k, &mut policy, &svc, 7, k,
            ));
        });
    }
}
