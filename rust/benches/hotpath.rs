//! Bench: L3 coordinator hot-path microbenchmarks (perf pass §Perf):
//! queue ops (uncontended *and* contended multi-producer/multi-consumer,
//! central mutex FIFO vs sharded work stealing vs lock-free MPMC rings,
//! the shard-storage sweep extended to k ∈ {16, 32}), monitor ticks,
//! policy decisions, record aggregation — everything on the request path
//! *except* the model compute — plus the M/G/k simulator swept over the
//! worker-pool sizes k ∈ {1, 2, 4, 8}.
//!
//! Emits `BENCH_hotpath.json` (name → ns/iter) so the perf trajectory
//! is tracked across PRs (CI diffs it against the committed
//! `BENCH_baseline.json`); the contended sweep is the acceptance gauge
//! for the sharded-queue work (sharded ≥ 2x central at k ≥ 4) and the
//! batched-dispatch sweep (B ∈ {1, 4, 8, 16}, both disciplines) is the
//! gauge for the batching executor (batched ≥ 1.5x single dispatch at
//! B = 8).

use std::sync::Arc;
use std::time::Duration;

use compass::experiments::common::{
    base_qps, base_qps_k, make_policy, offline_phase, simulate_ctx, ExperimentCtx,
};
use compass::metrics::{RequestRecord, RunSummary};
use compass::planner::{
    derive_plan, derive_plan_pools, AqmParams, LatencyProfile, ProfiledConfig,
    ThresholdMode,
};
use compass::serving::monitor::LoadMonitor;
use compass::serving::pool::{capacity_factor, parse_pools, PoolSpec};
use compass::serving::{
    Discipline, ElasticoPolicy, Popped, QueueBackend, RequestQueue, ShardedQueue, Topology,
};
use compass::sim::{simulate_topology, LognormalService};
use compass::util::bench::{bench, fast_mode, group, write_json, BenchResult};
use compass::util::Rng;
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

/// Push+pop pairs per thread in the contended sweep.
const MPMC_OPS: usize = 10_000;

/// k threads each driving `ops` push+pop pairs through one shared
/// central FIFO (every operation crosses the one mutex).
fn central_mpmc(k: usize, ops: usize) {
    let q: Arc<RequestQueue<(u64, f64)>> = Arc::new(RequestQueue::new(k * ops));
    std::thread::scope(|s| {
        for _ in 0..k {
            let q = q.clone();
            s.spawn(move || {
                for i in 0..ops {
                    q.push((i as u64, 0.0)).unwrap();
                    loop {
                        match q.pop_timeout(Duration::from_millis(100)) {
                            Ok(Some(item)) => {
                                std::hint::black_box(item);
                                break;
                            }
                            Ok(None) => {}
                            Err(e) => panic!("{e:?}"),
                        }
                    }
                }
            });
        }
    });
}

/// The same workload over a k-shard work-stealing queue: round-robin
/// producers, per-worker consumers, 1/k of the traffic per shard —
/// locked `VecDeque` shards or lock-free MPMC rings per `backend`.
fn sharded_mpmc(k: usize, ops: usize, backend: QueueBackend) {
    let q: Arc<ShardedQueue<(u64, f64)>> =
        Arc::new(ShardedQueue::new_backend(k * ops, k, backend));
    std::thread::scope(|s| {
        for w in 0..k {
            let q = q.clone();
            s.spawn(move || {
                for i in 0..ops {
                    q.push((i as u64, 0.0)).unwrap();
                    loop {
                        match q.pop_timeout(w, Duration::from_millis(100)) {
                            Popped::Item(item) => {
                                std::hint::black_box(item);
                                break;
                            }
                            Popped::TimedOut => {}
                            Popped::Closed => break,
                        }
                    }
                }
            });
        }
    });
}

/// Batched dispatch under contention: k producers flood the queue while
/// k consumers drain it in batches of up to `b` via `pop_batch` — one
/// lock acquisition per batch instead of per item. `shards == 1` is the
/// central discipline, `shards == k` the sharded one; `b == 1` is the
/// single-dispatch baseline the batch sweep is measured against.
fn mpmc_batched(k: usize, shards: usize, ops: usize, b: usize, backend: QueueBackend) {
    let q: Arc<ShardedQueue<(u64, f64)>> =
        Arc::new(ShardedQueue::new_backend(k * ops, shards, backend));
    std::thread::scope(|s| {
        let producers: Vec<_> = (0..k)
            .map(|_| {
                let q = q.clone();
                s.spawn(move || {
                    for i in 0..ops {
                        // Capacity = k·ops: a push can never fail Full.
                        q.push((i as u64, 0.0)).unwrap();
                    }
                })
            })
            .collect();
        for w in 0..k {
            let q = q.clone();
            s.spawn(move || loop {
                match q.pop_batch(w, b, Duration::from_millis(100)) {
                    Popped::Item(items) => {
                        std::hint::black_box(items);
                    }
                    Popped::TimedOut => {}
                    Popped::Closed => break,
                }
            });
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
    });
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    group("hotpath: L3 coordinator overhead");

    results.push(bench("queue push+pop x1k", 2, 100, || {
        let q: RequestQueue<(u64, f64)> = RequestQueue::new(4096);
        for i in 0..1000u64 {
            q.push((i, i as f64)).unwrap();
        }
        for _ in 0..1000 {
            std::hint::black_box(
                q.pop_timeout(std::time::Duration::from_millis(1)).unwrap(),
            );
        }
    }));

    results.push(bench("sharded queue push+pop x1k (1 thread, 4 shards)", 2, 100, || {
        let q: ShardedQueue<(u64, f64)> = ShardedQueue::new(4096, 4);
        for i in 0..1000u64 {
            q.push((i, i as f64)).unwrap();
        }
        for _ in 0..1000 {
            std::hint::black_box(
                q.pop_timeout(0, std::time::Duration::from_millis(1)),
            );
        }
    }));

    results.push(bench("monitor tick x1k", 2, 100, || {
        let m = LoadMonitor::new(0.3);
        for i in 0..1000 {
            m.on_arrival();
            std::hint::black_box(m.tick(i as f64 * 10.0));
        }
    }));

    let (_s, plan) = offline_phase(0.75, 1000.0, 7, false).unwrap();
    let mut policy = make_policy(&plan, "Elastico");
    results.push(bench("policy decide x1k", 2, 100, || {
        for i in 0..1000u64 {
            std::hint::black_box(policy.decide(i as f64, (i % 13) as usize));
        }
    }));

    // Metrics aggregation over a large run.
    let mut rng = Rng::new(3);
    let records: Vec<RequestRecord> = (0..100_000)
        .map(|i| {
            let arr = i as f64;
            RequestRecord {
                id: i,
                arrival_ms: arr,
                start_ms: arr + rng.uniform() * 5.0,
                finish_ms: arr + 5.0 + rng.uniform() * 100.0,
                config_idx: (i % 3) as usize,
                accuracy: 0.8,
                success: None,
            }
        })
        .collect();
    results.push(bench("RunSummary::compute 100k records", 1, 20, || {
        std::hint::black_box(RunSummary::compute(&records, &[], 100.0, 3));
    }));

    // Contended MPMC sweep: the single-threaded queue bench above cannot
    // see the coordinator mutex — k threads hammering push/pop can. The
    // central FIFO serializes all k on one lock; the sharded queue
    // spreads them over k shard locks plus one atomic depth counter.
    group("hotpath: contended queue (k threads x push+pop pairs)");
    let ops = if fast_mode() { MPMC_OPS / 10 } else { MPMC_OPS };
    for k in [1usize, 2, 4, 8] {
        results.push(bench(
            &format!("mpmc central k={k} push+pop x{ops}/thread"),
            1,
            10,
            || central_mpmc(k, ops),
        ));
    }
    // The shard-storage sweep extends past the central FIFO's range:
    // at k ∈ {16, 32} the interesting contention is shard-lock vs
    // lock-free CAS, not the central mutex (which the k ≤ 8 sweep
    // already shows losing).
    for k in [1usize, 2, 4, 8, 16, 32] {
        results.push(bench(
            &format!("mpmc sharded k={k} push+pop x{ops}/thread"),
            1,
            10,
            || sharded_mpmc(k, ops, QueueBackend::Mutex),
        ));
        results.push(bench(
            &format!("mpmc ring k={k} push+pop x{ops}/thread"),
            1,
            10,
            || sharded_mpmc(k, ops, QueueBackend::Ring),
        ));
    }

    // Batch-dispatch sweep: the acceptance gauge for the batching
    // executor. k = 4 producers flood the queue while 4 consumers drain
    // with pop_batch(B): at B = 1 every item costs a lock acquisition
    // (the single-dispatch baseline); deeper batches amortize it. Both
    // disciplines run so the central mutex and the sharded shard-locks
    // are each measured under batched drain.
    group("hotpath: batched dispatch (k=4 threads, pop_batch sweep)");
    let bk = 4usize;
    for b in [1usize, 4, 8, 16] {
        results.push(bench(
            &format!("mpmc batched central k={bk} B={b} x{ops}/thread"),
            1,
            10,
            || mpmc_batched(bk, 1, ops, b, QueueBackend::Mutex),
        ));
        results.push(bench(
            &format!("mpmc batched sharded k={bk} B={b} x{ops}/thread"),
            1,
            10,
            || mpmc_batched(bk, bk, ops, b, QueueBackend::Mutex),
        ));
        results.push(bench(
            &format!("mpmc batched ring k={bk} B={b} x{ops}/thread"),
            1,
            10,
            || mpmc_batched(bk, bk, ops, b, QueueBackend::Ring),
        ));
    }
    // High-contention batched drain: the one-CAS run/steal-half claim
    // vs one lock acquisition per batch, at thread counts where the
    // shard locks start to convoy.
    for k in [8usize, 16, 32] {
        for b in [1usize, 8] {
            results.push(bench(
                &format!("mpmc batched sharded k={k} B={b} x{ops}/thread"),
                1,
                10,
                || mpmc_batched(k, k, ops, b, QueueBackend::Mutex),
            ));
            results.push(bench(
                &format!("mpmc batched ring k={k} B={b} x{ops}/thread"),
                1,
                10,
                || mpmc_batched(k, k, ops, b, QueueBackend::Ring),
            ));
        }
    }

    // M/G/k coordinator sweep: the paper's spike trace replayed through
    // the discrete-event simulator at each pool size, with worker-aware
    // thresholds and pool-scaled load (per-worker ρ held constant). The
    // ladder itself is k-independent, so the search/profiling above is
    // not repeated: per-k plans re-derive thresholds from its profile.
    // Both dispatch disciplines run so the DES cost of the steal sweep
    // is visible alongside the ordering/latency deltas it models.
    group("hotpath: M/G/k simulator sweep");
    let front: Vec<ProfiledConfig> = plan
        .ladder
        .iter()
        .map(|p| ProfiledConfig {
            config: p.config.clone(),
            label: p.label.clone(),
            accuracy: p.accuracy,
            latency: LatencyProfile {
                mean_ms: p.mean_ms,
                p50_ms: p.mean_ms,
                p95_ms: p.p95_ms,
                runs: 0,
            },
        })
        .collect();
    for k in [1usize, 2, 4, 8] {
        let plan_k = derive_plan(&front, AqmParams::for_slo_workers(1000.0, k));
        let arrivals = generate_arrivals(&WorkloadSpec {
            base_qps: base_qps_k(&plan_k, k),
            duration_s: 180.0,
            pattern: Pattern::paper_spike(),
            seed: 7,
        });
        let svc = LognormalService::from_plan(&plan_k, 0.10);
        for disc in [Discipline::CentralFifo, Discipline::ShardedSteal] {
            let ctx = ExperimentCtx { workers: k, discipline: disc, ..ExperimentCtx::default() };
            results.push(bench(
                &format!("simulate spike 180s k={k} {}", disc.name()),
                1,
                20,
                || {
                    let mut policy = make_policy(&plan_k, "Elastico");
                    std::hint::black_box(
                        simulate_ctx(&ctx, &arrivals, &plan_k, &mut policy, &svc).unwrap(),
                    );
                },
            ));
        }
        // The disc shape driven through the unified engine directly
        // (no shim): the gate bounds it against the shim key above.
        if k == 4 {
            let topo = Topology::uniform(4, 4);
            results.push(bench("des_unified disc spike 180s k=4 sharded", 1, 20, || {
                let mut policy = ElasticoPolicy::new(plan_k.clone());
                std::hint::black_box(simulate_topology(
                    &arrivals, &plan_k, &mut policy, &svc, 7, &topo, 1,
                ));
            }));
        }
    }

    // Heterogeneous pool sweep: the same spike trace through the pooled
    // DES at three fleet shapes — a homogeneous 4-worker reference (the
    // parity case, directly comparable to `simulate spike 180s k=4
    // sharded`), and two fast+accurate splits. Plans are derived with
    // per-pool thresholds; load is scaled by the fleet's capacity factor
    // Σ w/speed so every topology runs at the same per-worker operating
    // point. One Erlang-C derivation key tracks the planner-side cost of
    // the waiting-probability thresholds.
    group("hotpath: heterogeneous pool DES sweep");
    let topologies: Vec<(&str, Vec<PoolSpec>)> = vec![
        ("homog fast x4", vec![PoolSpec::uniform(4)]),
        ("fast3+acc1", parse_pools("fast:3:1.0,accurate:1:2.5").unwrap()),
        ("fast2+acc2", parse_pools("fast:2:1.0,accurate:2:2.5").unwrap()),
    ];
    for (name, pools) in &topologies {
        let plan_p = derive_plan_pools(&front, AqmParams::for_slo(1000.0), pools);
        let arrivals = generate_arrivals(&WorkloadSpec {
            base_qps: capacity_factor(pools) * base_qps(&plan_p),
            duration_s: 180.0,
            pattern: Pattern::paper_spike(),
            seed: 7,
        });
        let svc = LognormalService::from_plan(&plan_p, 0.10);
        let ctx = ExperimentCtx { pools: pools.clone(), ..ExperimentCtx::default() };
        results.push(bench(
            &format!("simulate pools spike 180s {name}"),
            1,
            20,
            || {
                let mut policy = make_policy(&plan_p, "Elastico");
                std::hint::black_box(
                    simulate_ctx(&ctx, &arrivals, &plan_p, &mut policy, &svc).unwrap(),
                );
            },
        ));
        // The same pooled shape through the unified engine directly —
        // the `des_unified` gate key: the abstraction may not slow the
        // 180s x 24-cell replay (ratio vs the shim key bounded in
        // BENCH_baseline.json).
        let topo = Topology::from_pools(pools, 0.0).unwrap();
        results.push(bench(
            &format!("des_unified pooled spike 180s {name}"),
            1,
            20,
            || {
                let mut policy = ElasticoPolicy::new(plan_p.clone());
                std::hint::black_box(simulate_topology(
                    &arrivals, &plan_p, &mut policy, &svc, 7, &topo, 1,
                ));
            },
        ));
    }
    results.push(bench("derive_plan erlang k=4 x100", 1, 20, || {
        let params = AqmParams::for_slo_workers(1000.0, 4)
            .with_thresholds(ThresholdMode::ErlangC);
        for _ in 0..100 {
            std::hint::black_box(derive_plan(&front, params));
        }
    }));

    write_json("BENCH_hotpath.json", &results).expect("write BENCH_hotpath.json");

    // Quick acceptance readout for the sharded-queue work: contended
    // throughput ratio at each k (informational; CI greps the JSON).
    println!();
    let find = |name: String| {
        results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.summary_us.mean)
    };
    for k in [2usize, 4, 8] {
        if let (Some(c), Some(s)) = (
            find(format!("mpmc central k={k} push+pop x{ops}/thread")),
            find(format!("mpmc sharded k={k} push+pop x{ops}/thread")),
        ) {
            println!("contended speedup k={k}: {:.2}x (central/sharded)", c / s);
        }
    }
    // Ring acceptance readout: the lock-free shards against the locked
    // shards on the identical contended workload — the gate's bars are
    // ring >= 1.0x sharded at k=8 and <= 1.1x slower at k=1.
    for k in [1usize, 2, 4, 8, 16, 32] {
        if let (Some(s), Some(r)) = (
            find(format!("mpmc sharded k={k} push+pop x{ops}/thread")),
            find(format!("mpmc ring k={k} push+pop x{ops}/thread")),
        ) {
            println!("ring speedup k={k}: {:.2}x (sharded/ring)", s / r);
        }
    }
    for k in [8usize, 16, 32] {
        if let (Some(s), Some(r)) = (
            find(format!("mpmc batched sharded k={k} B=8 x{ops}/thread")),
            find(format!("mpmc batched ring k={k} B=8 x{ops}/thread")),
        ) {
            println!("ring batched speedup k={k} B=8: {:.2}x (sharded/ring)", s / r);
        }
    }
    // Batch acceptance readout: batched dispatch vs single dispatch
    // (B=1) on the same contended workload — the issue's bar is ≥1.5x
    // at B=8.
    for disc in ["central", "sharded", "ring"] {
        for b in [4usize, 8, 16] {
            if let (Some(b1), Some(bb)) = (
                find(format!("mpmc batched {disc} k={bk} B=1 x{ops}/thread")),
                find(format!("mpmc batched {disc} k={bk} B={b} x{ops}/thread")),
            ) {
                println!(
                    "batch speedup {disc} B={b}: {:.2}x (vs single dispatch)",
                    b1 / bb
                );
            }
        }
    }
    // Pooled-DES readout: the pooled event loop on a homogeneous fleet
    // should track the sharded DES cost (the gate's ratio bound), and
    // the heterogeneous splits show the routing/spill overhead.
    if let (Some(sharded), Some(pooled)) = (
        find("simulate spike 180s k=4 sharded".to_string()),
        find("simulate pools spike 180s homog fast x4".to_string()),
    ) {
        println!("pooled DES cost (homog k=4): {:.2}x vs sharded DES", pooled / sharded);
    }
    for het in ["fast3+acc1", "fast2+acc2"] {
        if let (Some(h), Some(homog)) = (
            find(format!("simulate pools spike 180s {het}")),
            find("simulate pools spike 180s homog fast x4".to_string()),
        ) {
            println!("heterogeneous DES cost {het}: {:.2}x vs homog pools", h / homog);
        }
    }
    // Unified-engine readout: the direct engine against the shim keys —
    // the gate bounds these ratios at ≤ 1.15x so the one-engine
    // abstraction can never silently slow the experiment replay.
    for (unified, shim) in [
        ("des_unified disc spike 180s k=4 sharded", "simulate spike 180s k=4 sharded"),
        (
            "des_unified pooled spike 180s homog fast x4",
            "simulate pools spike 180s homog fast x4",
        ),
    ] {
        if let (Some(u), Some(s)) = (find(unified.to_string()), find(shim.to_string())) {
            println!("unified engine cost [{unified}]: {:.2}x vs shim", u / s);
        }
    }
}
