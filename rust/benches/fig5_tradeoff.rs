//! Bench: serving-cell simulation throughput (paper Fig. 5 machinery) —
//! one full 180s spike cell per policy through the discrete-event engine.
use compass::experiments::common::{base_qps, make_policy, offline_phase, simulate_boxed};
use compass::sim::LognormalService;
use compass::util::bench::{bench, group};
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

fn main() {
    group("fig5: 180s serving cells (sim)");
    let (_s, full) = offline_phase(0.75, 1e9, 7, false).unwrap();
    let slo = 2.2 * full.ladder.last().unwrap().mean_ms;
    let (_s2, plan) = offline_phase(0.75, slo, 7, false).unwrap();
    let arrivals = generate_arrivals(&WorkloadSpec {
        base_qps: base_qps(&full),
        duration_s: 180.0,
        pattern: Pattern::paper_spike(),
        seed: 7,
    });
    for policy_name in ["Elastico", "Static-Fast", "Static-Accurate"] {
        let policy_plan = if policy_name == "Elastico" { &plan } else { &full };
        let svc = LognormalService::from_plan(policy_plan, 0.10);
        bench(&format!("sim 180s spike {policy_name}"), 1, 20, || {
            let mut policy = make_policy(policy_plan, policy_name);
            let out = simulate_boxed(&arrivals, policy_plan, &mut policy, &svc, 7);
            std::hint::black_box(out.records.len());
        });
    }
}
