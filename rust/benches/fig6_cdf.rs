//! Bench: latency-CDF extraction (paper Fig. 6 post-processing).
use compass::experiments::common::{base_qps, make_policy, offline_phase, simulate_boxed};
use compass::metrics::latency_cdf;
use compass::sim::LognormalService;
use compass::util::bench::{bench, group};
use compass::workload::{generate_arrivals, Pattern, WorkloadSpec};

fn main() {
    group("fig6: CDF extraction over a spike run");
    let (_s, plan) = offline_phase(0.75, 1e9, 7, false).unwrap();
    let arrivals = generate_arrivals(&WorkloadSpec {
        base_qps: base_qps(&plan),
        duration_s: 180.0,
        pattern: Pattern::paper_spike(),
        seed: 7,
    });
    let svc = LognormalService::from_plan(&plan, 0.10);
    let mut policy = make_policy(&plan, "Elastico");
    let out = simulate_boxed(&arrivals, &plan, &mut policy, &svc, 7);
    bench("latency_cdf 200pt", 2, 50, || {
        std::hint::black_box(latency_cdf(&out.records, 200));
    });
}
