//! Bench: Elastico decision latency (the paper's <10ms switch budget) and
//! the Fig. 7 timeline generation.
use compass::experiments::common::{make_policy, offline_phase};
use compass::serving::policy::ScalingPolicy;
use compass::serving::ElasticoPolicy;
use compass::util::bench::{bench, group};

fn main() {
    group("fig7: controller decision hot path");
    let (_s, plan) = offline_phase(0.75, 1000.0, 7, false).unwrap();
    let mut ela = ElasticoPolicy::new(plan.clone());
    let mut t = 0.0;
    bench("elastico.decide x10k", 2, 50, || {
        for i in 0..10_000u64 {
            t += 1.0;
            std::hint::black_box(ela.decide(t, (i % 17) as usize));
        }
    });
    bench("make_policy Elastico (switch setup)", 2, 100, || {
        std::hint::black_box(make_policy(&plan, "Elastico").current());
    });
}
