//! Bench: live per-configuration profiling cost (paper Fig. 1 inputs) —
//! one request per ladder extreme through the real PJRT pipeline.
//! Requires `make artifacts`; skips gracefully otherwise.
use compass::configspace::rag_space;
use compass::runtime::artifacts_dir;
use compass::util::bench::{bench, group};
use compass::workflows::rag::RagWorkflow;
use compass::workflows::Workflow;

fn main() {
    group("fig1: live RAG request per ladder extreme");
    if !artifacts_dir().join("manifest.json").exists() {
        println!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let space = rag_space();
    let mut wf = RagWorkflow::load(&artifacts_dir(), 7).unwrap();
    for (label, cfg) in [
        ("fastest (gen-64,3,1,rr-48)", vec![0usize, 0, 0, 0]),
        ("mid (gen-128,10,3,rr-96)", vec![2, 2, 1, 1]),
        ("accurate (gen-288,20,3,rr-160)", vec![5, 3, 1, 2]),
    ] {
        bench(label, 2, 10, || {
            std::hint::black_box(wf.run(&space, &cfg).unwrap());
        });
    }
}
