"""AOT lowering: JAX models -> HLO text + weight bins + manifest.json.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  Lowering goes stablehlo -> XlaComputation (``return_tuple=True``)
-> ``as_hlo_text()``; the Rust side unwraps with ``to_tuple<N>``.

Weights are exported as raw little-endian f32 blobs (one per model) and
listed in the manifest in argument order; the Rust runtime uploads them to
device buffers once at startup, so the request path is Python-free *and*
weight-copy-free.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import hashlib
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.common import ModelDef
from compile.model import all_models

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """Convert a jax lowering to HLO text via stablehlo -> XlaComputation."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(model: ModelDef) -> str:
    """Lower ``apply(params, *inputs)`` with params as runtime arguments."""
    n_params = len(model.params)

    def flat_apply(*args):
        return model.apply(list(args[:n_params]), *args[n_params:])

    arg_specs = [
        jax.ShapeDtypeStruct(a.shape, jnp.float32) for _, a in model.params
    ] + [
        jax.ShapeDtypeStruct(io.shape, _DTYPES[io.dtype]) for io in model.inputs
    ]
    lowered = jax.jit(flat_apply).lower(*arg_specs)
    return to_hlo_text(lowered)


def output_specs(model: ModelDef):
    """Evaluate output shapes/dtypes without running the model."""
    n_params = len(model.params)

    def flat_apply(*args):
        return model.apply(list(args[:n_params]), *args[n_params:])

    arg_specs = [
        jax.ShapeDtypeStruct(a.shape, jnp.float32) for _, a in model.params
    ] + [
        jax.ShapeDtypeStruct(io.shape, _DTYPES[io.dtype]) for io in model.inputs
    ]
    outs = jax.eval_shape(flat_apply, *arg_specs)
    dtname = {jnp.dtype("float32"): "f32", jnp.dtype("int32"): "i32"}
    return [
        {"shape": list(o.shape), "dtype": dtname[jnp.dtype(o.dtype)]}
        for o in jax.tree_util.tree_leaves(outs)
    ]


def export_model(model: ModelDef, out_dir: Path) -> dict:
    """Lower one model; write HLO + weights bin; return its manifest entry."""
    t0 = time.time()
    hlo = lower_model(model)
    hlo_path = out_dir / f"{model.name}.hlo.txt"
    hlo_path.write_text(hlo)

    entry = {
        "hlo": hlo_path.name,
        "kind": model.kind,
        "meta": model.meta,
        "inputs": [
            {"name": io.name, "shape": list(io.shape), "dtype": io.dtype}
            for io in model.inputs
        ],
        "outputs": output_specs(model),
        "params": [],
    }

    if model.params:
        weights = model.flat_weights()
        blob = weights.tobytes()  # little-endian f32 on all supported hosts
        bin_path = out_dir / "weights" / f"{model.name}.bin"
        bin_path.parent.mkdir(exist_ok=True)
        bin_path.write_bytes(blob)
        entry["weights_bin"] = f"weights/{bin_path.name}"
        entry["weights_sha256"] = hashlib.sha256(blob).hexdigest()
        offset = 0
        for name, arr in model.params:
            n = int(arr.size)
            entry["params"].append(
                {"name": name, "shape": list(arr.shape), "offset": offset,
                 "numel": n}
            )
            offset += n

    dt = time.time() - t0
    print(
        f"  {model.name:<12} kind={model.kind:<9} hlo={len(hlo)//1024:>6} KiB "
        f"params={sum(a.size for _, a in model.params):>9,} ({dt:.1f}s)"
    )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--only", default=None,
        help="comma-separated model names (default: all)",
    )
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    models = all_models()
    if args.only:
        keep = set(args.only.split(","))
        models = [m for m in models if m.name in keep]

    print(f"AOT-lowering {len(models)} models -> {out_dir}")
    manifest = {"version": 1, "artifacts": {}}
    for model in models:
        manifest["artifacts"][model.name] = export_model(model, out_dir)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
