"""L1 Pallas kernels for the Compass compound-AI workflows.

Every kernel is written TPU-style (BlockSpec-expressed HBM->VMEM schedule,
MXU-friendly tile shapes) but lowered with ``interpret=True`` so the emitted
HLO runs on any PJRT backend, including the Rust CPU client that serves
requests at runtime.  Pure-jnp oracles live in :mod:`compile.kernels.ref`;
``python/tests/test_kernels.py`` checks every kernel against its oracle with
hypothesis-driven shape/seed sweeps.
"""

from compile.kernels.attention import mha_prefill
from compile.kernels.decode_attention import mha_decode
from compile.kernels.rmsnorm_matmul import rmsnorm_matmul
from compile.kernels.retrieval import retrieval_scores

__all__ = [
    "mha_prefill",
    "mha_decode",
    "rmsnorm_matmul",
    "retrieval_scores",
]
