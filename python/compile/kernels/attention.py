"""Flash-style causal multi-head attention (prefill) as a Pallas kernel.

TPU adaptation of the GPU flash-attention insight (§3 of DESIGN.md):

* the GPU version tiles Q across threadblocks and streams K/V through
  shared memory; here each grid step owns one ``(head, q-block)`` tile
  resident in VMEM and streams K/V **chunks** through an online-softmax
  ``fori_loop`` — the VMEM-blocked analogue of the SRAM-blocked loop;
* tile sizes are multiples of 8x128-friendly shapes so the q @ k^T and
  p @ v contractions map onto the MXU systolic array;
* accumulation is f32 regardless of input dtype (MXU accumulate width).

Lowered with ``interpret=True`` for CPU-PJRT execution (real-TPU lowering
emits a Mosaic custom-call the CPU plugin cannot run — see DESIGN.md §3).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. Q_BLOCK rows of queries are resident per grid step;
# K/V are streamed in K_CHUNK-row chunks by the inner online-softmax loop.
Q_BLOCK = 32
K_CHUNK = 32

_NEG_INF = -1e30


def _mha_prefill_kernel(q_ref, k_ref, v_ref, o_ref, *, k_chunk: int, causal: bool):
    """One grid step: queries block (one head) against all K/V chunks.

    Block shapes (leading head axis is blocked to 1):
      q_ref: (1, bq, dh)   o_ref: (1, bq, dh)
      k_ref: (1, s, dh)    v_ref: (1, s, dh)
    """
    q = q_ref[0].astype(jnp.float32)  # (bq, dh)
    bq, dh = q.shape
    s = k_ref.shape[1]
    scale = 1.0 / (dh**0.5)
    q = q * scale

    q_block = pl.program_id(1)
    q_pos = q_block * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    n_chunks = s // k_chunk

    def body(i, carry):
        # Online-softmax accumulation over one K/V chunk: the streaming
        # analogue of flash attention's SRAM block loop.
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.ds(i * k_chunk, k_chunk), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(i * k_chunk, k_chunk), :].astype(jnp.float32)
        logits = q @ k.T  # (bq, k_chunk) — MXU contraction
        if causal:
            k_pos = i * k_chunk + jax.lax.broadcasted_iota(
                jnp.int32, (1, k_chunk), 1
            )
            logits = jnp.where(k_pos <= q_pos, logits, _NEG_INF)
        m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        acc = alpha * acc + p @ v  # MXU contraction
        return m_new, l_new, acc

    m0 = jnp.full((bq, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc0 = jnp.zeros((bq, dh), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "q_block", "k_chunk"))
def mha_prefill(q, k, v, *, causal=True, q_block=Q_BLOCK, k_chunk=K_CHUNK):
    """Multi-head attention over full sequences (prefill phase).

    Args:
      q, k, v: ``(heads, seq, head_dim)`` arrays (same dtype).
      causal: apply a causal mask (decoder self-attention).
      q_block / k_chunk: VMEM tile sizes; must divide ``seq``.

    Returns:
      ``(heads, seq, head_dim)`` attention output.
    """
    h, s, dh = q.shape
    bq = min(q_block, s)
    kc = min(k_chunk, s)
    if s % bq or s % kc:
        raise ValueError(f"seq={s} must be divisible by tiles ({bq}, {kc})")
    grid = (h, s // bq)
    return pl.pallas_call(
        functools.partial(_mha_prefill_kernel, k_chunk=kc, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((1, s, dh), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, dh), q.dtype),
        interpret=True,
    )(q, k, v)
