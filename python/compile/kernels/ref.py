"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness ground truth: straightforward, unfused jnp
implementations with no tiling, checked against the kernels by
``python/tests/test_kernels.py`` (hypothesis sweeps over shapes/seeds).
"""

import jax.numpy as jnp

_NEG_INF = -1e30

EPS = 1e-6


def mha_prefill_ref(q, k, v, *, causal=True):
    """Reference multi-head attention: (h, s, dh) -> (h, s, dh)."""
    h, s, dh = q.shape
    logits = jnp.einsum("hqd,hkd->hqk", q, k).astype(jnp.float32) / (dh**0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, :, :], logits, _NEG_INF)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def mha_decode_ref(q, k_cache, v_cache, length):
    """Reference decode attention: (h, dh) vs (h, smax, dh) caches."""
    h, smax, dh = k_cache.shape
    logits = jnp.einsum("hd,hsd->hs", q, k_cache).astype(jnp.float32) / (dh**0.5)
    pos = jnp.arange(smax)[None, :]
    logits = jnp.where(pos < jnp.asarray(length, jnp.int32).reshape(()), logits, _NEG_INF)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hs,hsd->hd", p, v_cache.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, gain):
    """Reference RMSNorm over the last axis."""
    ms = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * (ms + EPS) ** -0.5 * gain).astype(x.dtype)


def rmsnorm_matmul_ref(x, gain, w):
    """Reference fused rmsnorm->matmul: (r, d), (d,), (d, f) -> (r, f)."""
    xn = rmsnorm_ref(x, gain).astype(jnp.float32)
    return (xn @ w.astype(jnp.float32)).astype(x.dtype)


def retrieval_scores_ref(corpus, query):
    """Reference retrieval scores: (n, d), (d,) -> (n,)."""
    return (corpus.astype(jnp.float32) @ query.astype(jnp.float32)).astype(
        corpus.dtype
    )
