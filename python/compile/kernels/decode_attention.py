"""Single-token (decode) attention over a KV cache as a Pallas kernel.

The GPU formulation of decode attention is a warp-cooperative matvec over
the KV cache; the TPU adaptation is a VMEM-blocked row reduction: each grid
step owns one head, the cache is streamed through ``k_chunk``-row tiles and
reduced with an online softmax.  Entries at positions ``>= length`` (the
not-yet-written tail of the cache) are masked out via a broadcasted iota
compare — the Pallas analogue of the GPU version's lane predicate.

Lowered with ``interpret=True`` (see attention.py docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_CHUNK = 32

_NEG_INF = -1e30


def _mha_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, k_chunk: int):
    """Block shapes: q (1, dh); k/v (1, smax, dh); len (1,); o (1, dh)."""
    q = q_ref[0].astype(jnp.float32)  # (dh,)
    dh = q.shape[0]
    smax = k_ref.shape[1]
    length = len_ref[0]
    scale = 1.0 / (dh**0.5)
    q = (q * scale)[None, :]  # (1, dh)

    n_chunks = smax // k_chunk

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.ds(i * k_chunk, k_chunk), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(i * k_chunk, k_chunk), :].astype(jnp.float32)
        logits = q @ k.T  # (1, k_chunk)
        pos = i * k_chunk + jax.lax.broadcasted_iota(jnp.int32, (1, k_chunk), 1)
        logits = jnp.where(pos < length, logits, _NEG_INF)
        m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        acc = alpha * acc + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((1, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((1, 1), jnp.float32)
    acc0 = jnp.zeros((1, dh), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, acc0))
    o_ref[0] = (acc[0] / l[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("k_chunk",))
def mha_decode(q, k_cache, v_cache, length, *, k_chunk=K_CHUNK):
    """Attention for one new token against a (padded) KV cache.

    Args:
      q: ``(heads, head_dim)`` query for the current position.
      k_cache, v_cache: ``(heads, smax, head_dim)`` padded caches.
      length: scalar or ``(1,)`` int32 — number of valid cache rows
        (the current position + 1; rows ``>= length`` are masked).
      k_chunk: cache tile size; must divide ``smax``.

    Returns:
      ``(heads, head_dim)`` attention output.
    """
    h, smax, dh = k_cache.shape
    kc = min(k_chunk, smax)
    if smax % kc:
        raise ValueError(f"smax={smax} must be divisible by k_chunk={kc}")
    length = jnp.asarray(length, jnp.int32).reshape((1,))
    return pl.pallas_call(
        functools.partial(_mha_decode_kernel, k_chunk=kc),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1,), lambda hi: (0,)),
            pl.BlockSpec((1, dh), lambda hi: (hi, 0)),
            pl.BlockSpec((1, smax, dh), lambda hi: (hi, 0, 0)),
            pl.BlockSpec((1, smax, dh), lambda hi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda hi: (hi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, dh), q.dtype),
        interpret=True,
    )(length, q, k_cache, v_cache)
