"""Fused RMSNorm -> matmul as a Pallas kernel.

The GPU idiom is a fused epilogue/prologue: normalize the activation tile
in registers right before the tensor-core GEMM so the normalized tensor
never round-trips to HBM.  The TPU analogue implemented here: each grid
step owns an ``(rows x d)`` activation tile and a ``(d x f_block)`` weight
tile in VMEM, computes the row RMS statistics in-register, scales, and
feeds the MXU contraction directly.

out[r, f] = (x[r, :] / rms(x[r, :]) * g[:]) @ w[:, f]

Lowered with ``interpret=True`` (see attention.py docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 32
COL_BLOCK = 128

EPS = 1e-6


def _rmsnorm_matmul_kernel(x_ref, g_ref, w_ref, o_ref):
    """Block shapes: x (br, d); g (d,); w (d, bf); o (br, bf)."""
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    # Row RMS statistics computed in-register on the resident tile.
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    xn = x * jax.lax.rsqrt(ms + EPS) * g[None, :]
    o_ref[...] = (xn @ w).astype(o_ref.dtype)  # MXU contraction


@functools.partial(jax.jit, static_argnames=("row_block", "col_block"))
def rmsnorm_matmul(x, gain, w, *, row_block=ROW_BLOCK, col_block=COL_BLOCK):
    """Fused ``rmsnorm(x) * gain @ w``.

    Args:
      x: ``(rows, d)`` activations.
      gain: ``(d,)`` RMSNorm gain.
      w: ``(d, f)`` weight matrix.
      row_block / col_block: VMEM tile sizes (clamped; must divide dims).

    Returns:
      ``(rows, f)`` output.
    """
    rows, d = x.shape
    d2, f = w.shape
    if d != d2 or gain.shape != (d,):
        raise ValueError(f"shape mismatch: x={x.shape} gain={gain.shape} w={w.shape}")
    br = min(row_block, rows)
    bf = min(col_block, f)
    if rows % br or f % bf:
        raise ValueError(f"dims ({rows},{f}) must be divisible by tiles ({br},{bf})")
    grid = (rows // br, f // bf)
    return pl.pallas_call(
        _rmsnorm_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda ri, fi: (ri, 0)),
            pl.BlockSpec((d,), lambda ri, fi: (0,)),
            pl.BlockSpec((d, bf), lambda ri, fi: (0, fi)),
        ],
        out_specs=pl.BlockSpec((br, bf), lambda ri, fi: (ri, fi)),
        out_shape=jax.ShapeDtypeStruct((rows, f), x.dtype),
        interpret=True,
    )(x, gain, w)
