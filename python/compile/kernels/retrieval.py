"""Retrieval similarity scoring as a Pallas kernel.

The retriever hot-spot of the RAG workflow: dot-product similarity of one
query embedding against the whole corpus embedding matrix.  Each grid step
streams one ``(n_block x d)`` corpus tile into VMEM and produces its score
slice — the HBM->VMEM schedule a GPU kernel would express with threadblock
tiling over the corpus rows.

Lowered with ``interpret=True`` (see attention.py docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_BLOCK = 64


def _retrieval_kernel(c_ref, q_ref, o_ref):
    """Block shapes: c (bn, d); q (d,); o (bn,)."""
    c = c_ref[...].astype(jnp.float32)
    q = q_ref[...].astype(jnp.float32)
    o_ref[...] = (c @ q).astype(o_ref.dtype)  # (bn,) MXU matvec


@functools.partial(jax.jit, static_argnames=("n_block",))
def retrieval_scores(corpus, query, *, n_block=N_BLOCK):
    """Dot-product scores of ``query`` against every corpus row.

    Args:
      corpus: ``(n, d)`` document embedding matrix.
      query: ``(d,)`` query embedding.
      n_block: corpus tile rows per grid step (must divide ``n``).

    Returns:
      ``(n,)`` similarity scores.
    """
    n, d = corpus.shape
    if query.shape != (d,):
        raise ValueError(f"query shape {query.shape} != ({d},)")
    bn = min(n_block, n)
    if n % bn:
        raise ValueError(f"n={n} must be divisible by n_block={bn}")
    return pl.pallas_call(
        _retrieval_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), corpus.dtype),
        interpret=True,
    )(corpus, query)
