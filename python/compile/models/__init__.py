"""L2 JAX model zoo for the Compass workflows.

Six decoder-only generator LMs, three cross-encoder rerankers, one
embedding retriever, three detector CNNs and three verifier CNNs — the
synthetic stand-ins for the paper's LLaMA3/Gemma3 generators, BGE/MS-MARCO
rerankers and YOLOv8 cascade (DESIGN.md §2 documents the substitution).
"""

from compile.models.transformer import GENERATORS, build_generator
from compile.models.reranker import RERANKERS, build_reranker
from compile.models.retriever import build_retriever, RETRIEVER_SPEC
from compile.models.detector import DETECTORS, VERIFIERS, build_detector, build_verifier

__all__ = [
    "GENERATORS",
    "RERANKERS",
    "DETECTORS",
    "VERIFIERS",
    "RETRIEVER_SPEC",
    "build_generator",
    "build_reranker",
    "build_retriever",
    "build_detector",
    "build_verifier",
]
