"""Decoder-only generator LMs (the RAG workflow's "LLM" component).

Six sizes mirror the paper's generator ladder (LLaMA3 1/3/8B, Gemma3
1/4/12B): service time grows monotonically with ``d_model`` x ``n_layers``
exactly as the paper's models do on the RTX 4090, which is the property
Compass consumes (DESIGN.md §2).

The exported artifact is a **single fused generation function**: prefill
over the packed prompt (retrieved docs + query, padded to ``SEQ`` tokens)
followed by a ``GEN_LEN``-step greedy decode loop.  The KV cache is a loop
carry, so it never leaves the device and the Rust request path makes
exactly one ``execute_b`` call per generation.

Hot spots run through the L1 Pallas kernels:
  * prefill attention  -> :func:`compile.kernels.mha_prefill`
  * decode attention   -> :func:`compile.kernels.mha_decode`
  * rmsnorm -> matmul  -> :func:`compile.kernels.rmsnorm_matmul`
"""

import dataclasses
import functools
from typing import List

import jax
import jax.numpy as jnp

from compile.common import IoSpec, ModelDef, ParamBuilder, largest_divisor_leq
from compile.kernels import mha_decode, mha_prefill, rmsnorm_matmul

VOCAB = 256
SEQ = 64  # packed prompt length (docs + query, harness pads)
GEN_LEN = 16  # greedy decode steps per request
SMAX = 96  # KV cache capacity (>= SEQ + GEN_LEN, tile friendly)
HEAD_DIM = 32
MLP_RATIO = 4


@dataclasses.dataclass(frozen=True)
class TransformerSpec:
    name: str
    alias: str  # the paper's model this stands in for
    d_model: int
    n_layers: int
    seed: int

    @property
    def n_heads(self) -> int:
        return self.d_model // HEAD_DIM

    @property
    def d_mlp(self) -> int:
        return self.d_model * MLP_RATIO

    def flops_per_token(self) -> int:
        """Approx forward FLOPs per token (2x MACs), for roofline estimates."""
        d = self.d_model
        per_layer = 2 * (4 * d * d + 2 * d * self.d_mlp)  # qkv+o, up+down
        return self.n_layers * per_layer + 2 * d * VOCAB


GENERATORS: List[TransformerSpec] = [
    TransformerSpec("gen-64", "llama3.2:1b", 64, 2, 1001),
    TransformerSpec("gen-96", "gemma3:1b", 96, 2, 1002),
    TransformerSpec("gen-128", "llama3.2:3b", 128, 3, 1003),
    TransformerSpec("gen-160", "gemma3:4b", 160, 4, 1004),
    TransformerSpec("gen-224", "llama3.1:8b", 224, 5, 1005),
    TransformerSpec("gen-288", "gemma3:12b", 288, 6, 1006),
]


def make_params(spec: TransformerSpec) -> ParamBuilder:
    """Deterministic parameter set in flatten order (matches manifest)."""
    pb = ParamBuilder(spec.seed)
    d = spec.d_model
    pb.gauss("embed", (VOCAB, d), 0.05)
    pb.gauss("pos_embed", (SMAX, d), 0.02)
    for i in range(spec.n_layers):
        pb.ones(f"l{i}.attn_gain", (d,))
        pb.dense(f"l{i}.wqkv", d, 3 * d)
        pb.dense(f"l{i}.wo", d, d)
        pb.ones(f"l{i}.mlp_gain", (d,))
        pb.dense(f"l{i}.w_up", d, spec.d_mlp)
        pb.dense(f"l{i}.w_down", spec.d_mlp, d)
    pb.ones("out_gain", (d,))
    pb.dense("w_out", d, VOCAB)
    return pb


def _unpack(spec: TransformerSpec, params):
    """Split the flat param list into (embeds, per-layer, head) groups."""
    it = iter(params)
    embed, pos = next(it), next(it)
    layers = []
    for _ in range(spec.n_layers):
        layers.append(tuple(next(it) for _ in range(6)))
    out_gain, w_out = next(it), next(it)
    return embed, pos, layers, out_gain, w_out


def _fused_norm_matmul(x, gain, w):
    """rmsnorm->matmul through the Pallas kernel.

    CPU-artifact tiling: one grid step over the whole operand (interpret
    mode executes each grid step as an HLO loop iteration, so extra steps
    are pure overhead — the §Perf pass measured 6x on gen-288). The
    TPU-targeted tile choice (rows<=32, cols<=128 for VMEM residency) is
    exercised by the kernel test suite instead."""
    return rmsnorm_matmul(x, gain, w, row_block=x.shape[0], col_block=w.shape[1])


def _block_prefill(x, layer, spec: TransformerSpec):
    """One transformer block over the full prompt; returns (x, k, v)."""
    attn_gain, wqkv, wo, mlp_gain, w_up, w_down = layer
    s, d = x.shape
    h, dh = spec.n_heads, HEAD_DIM
    qkv = _fused_norm_matmul(x, attn_gain, wqkv)  # (s, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(s, h, dh).transpose(1, 0, 2)
    k = k.reshape(s, h, dh).transpose(1, 0, 2)
    v = v.reshape(s, h, dh).transpose(1, 0, 2)
    attn = mha_prefill(q, k, v, causal=True, q_block=s, k_chunk=s)
    attn = attn.transpose(1, 0, 2).reshape(s, d)
    x = x + attn @ wo
    up = _fused_norm_matmul(x, mlp_gain, w_up)
    x = x + jax.nn.gelu(up) @ w_down
    return x, k, v


def _block_decode(x, layer, kc, vc, pos, spec: TransformerSpec):
    """One block for a single token against the KV cache.

    Args:
      x: (1, d) current activation.  kc/vc: (h, smax, dh) caches.
      pos: scalar i32 current position (cache rows < pos are valid).
    Returns: (x, kc, vc) with the new K/V row written at ``pos``.
    """
    attn_gain, wqkv, wo, mlp_gain, w_up, w_down = layer
    d = x.shape[1]
    h, dh = spec.n_heads, HEAD_DIM
    qkv = _fused_norm_matmul(x, attn_gain, wqkv)  # (1, 3d)
    q, k, v = jnp.split(qkv[0], 3)
    q = q.reshape(h, dh)
    kc = jax.lax.dynamic_update_slice(kc, k.reshape(h, 1, dh), (0, pos, 0))
    vc = jax.lax.dynamic_update_slice(vc, v.reshape(h, 1, dh), (0, pos, 0))
    attn = mha_decode(q, kc, vc, pos + 1, k_chunk=SMAX)  # (h, dh)
    x = x + attn.reshape(1, d) @ wo
    up = _fused_norm_matmul(x, mlp_gain, w_up)
    x = x + jax.nn.gelu(up) @ w_down
    return x, kc, vc


def prefill(spec: TransformerSpec, params, tokens):
    """Full-prompt forward. Returns (last_logits [V], k_caches, v_caches).

    Caches are ``(n_layers, h, SMAX, dh)`` with rows ``>= SEQ`` zero.
    """
    embed, pos_embed, layers, out_gain, w_out = _unpack(spec, params)
    s = tokens.shape[0]
    x = embed[tokens] + pos_embed[:s]
    ks, vs = [], []
    for layer in layers:
        x, k, v = _block_prefill(x, layer, spec)
        pad = SMAX - s
        ks.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0))))
        vs.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0))))
    logits = _fused_norm_matmul(x[-1:], out_gain, w_out)[0]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode_step(spec: TransformerSpec, params, token, pos, k_caches, v_caches):
    """Single-token forward at ``pos``. Returns (logits, k_caches, v_caches)."""
    embed, pos_embed, layers, out_gain, w_out = _unpack(spec, params)
    x = (embed[token] + pos_embed[pos]).reshape(1, -1)
    new_k, new_v = [], []
    for i, layer in enumerate(layers):
        x, kc, vc = _block_decode(x, layer, k_caches[i], v_caches[i], pos, spec)
        new_k.append(kc)
        new_v.append(vc)
    logits = _fused_norm_matmul(x, out_gain, w_out)[0]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def generate(spec: TransformerSpec, params, tokens):
    """Fused prefill + GEN_LEN-step greedy decode (the exported artifact).

    Returns:
      gen_tokens: (GEN_LEN,) i32 greedy continuation.
      score: scalar f32 — mean max-softmax probability over decode steps
        (the generator's self-confidence signal used by the harness).
    """
    logits, kc, vc = prefill(spec, params, tokens)

    def body(carry, _):
        logits, kc, vc, pos = carry
        tok = jnp.argmax(logits).astype(jnp.int32)
        prob = jax.nn.softmax(logits)[tok]
        logits2, kc2, vc2 = decode_step(spec, params, tok, pos, kc, vc)
        return (logits2, kc2, vc2, pos + 1), (tok, prob)

    (_, _, _, _), (toks, probs) = jax.lax.scan(
        body, (logits, kc, vc, jnp.int32(SEQ)), None, length=GEN_LEN
    )
    return toks, jnp.mean(probs)


def build_generator(spec: TransformerSpec) -> ModelDef:
    """Package a generator as an AOT-exportable ModelDef."""
    pb = make_params(spec)

    def apply(params, tokens):
        return generate(spec, params, tokens)

    return ModelDef(
        name=spec.name,
        kind="generator",
        params=pb.params,
        apply=apply,
        inputs=[IoSpec("tokens", (SEQ,), "i32")],
        meta={
            "alias": spec.alias,
            "d_model": spec.d_model,
            "n_layers": spec.n_layers,
            "n_heads": spec.n_heads,
            "vocab": VOCAB,
            "seq": SEQ,
            "gen_len": GEN_LEN,
            "smax": SMAX,
            "flops_per_token": spec.flops_per_token(),
        },
    )
