"""Detector / verifier CNNs (the object-detection cascade workflow).

Stand-ins for the paper's YOLOv8 n/s/m detectors and m/l/x verifiers
(DESIGN.md §2): conv stacks of increasing width whose compute cost scales
the way the YOLO ladder does.  The detector emits a per-cell confidence
map; the Rust cascade executor gates on its max (z-scored online) against
the configuration's confidence threshold to decide whether the verifier
runs — so the *fraction of inputs paying the verifier cost* varies with
the threshold exactly as in the paper's cascade.
"""

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
from jax import lax

from compile.common import IoSpec, ModelDef, ParamBuilder

IMG = 32  # input image side (NHWC, 3 channels)
GRID = 8  # detector output grid side
N_CLASSES = 8


@dataclasses.dataclass(frozen=True)
class CnnSpec:
    name: str
    alias: str
    width: int  # base channel count
    extra_convs: int  # depth knob
    seed: int


DETECTORS: List[CnnSpec] = [
    CnnSpec("det-n", "yolov8n", 16, 0, 3001),
    CnnSpec("det-s", "yolov8s", 24, 1, 3002),
    CnnSpec("det-m", "yolov8m", 32, 2, 3003),
]

VERIFIERS: List[CnnSpec] = [
    CnnSpec("ver-m", "yolov8m-verify", 32, 1, 3101),
    CnnSpec("ver-l", "yolov8l-verify", 48, 2, 3102),
    CnnSpec("ver-x", "yolov8x-verify", 64, 3, 3103),
]


def make_params(spec: CnnSpec, head_out: int) -> ParamBuilder:
    pb = ParamBuilder(spec.seed)
    w = spec.width
    chans = [3, w, 2 * w] + [2 * w] * spec.extra_convs
    for i in range(len(chans) - 1):
        fan_in = chans[i] * 9
        pb.gauss(f"conv{i}", (3, 3, chans[i], chans[i + 1]), fan_in**-0.5)
        pb.gauss(f"bias{i}", (chans[i + 1],), 0.01)
    feat = GRID * GRID * chans[-1]
    pb.gauss("w_head", (feat, head_out), feat**-0.5)
    pb.gauss("b_head", (head_out,), 0.01)
    return pb


def _conv_stack(spec: CnnSpec, params, image):
    """Shared conv trunk: (IMG, IMG, 3) -> (GRID*GRID*C,) features."""
    it = iter(params)
    x = image[None]  # NHWC batch 1
    n_convs = 2 + spec.extra_convs
    for i in range(n_convs):
        w = next(it)
        b = next(it)
        stride = 2 if i < 2 else 1  # two downsamples: 32 -> 16 -> 8
        x = lax.conv_general_dilated(
            x, w, window_strides=(stride, stride), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        x = jax.nn.relu(x + b)
    return x.reshape(-1), it


def detect(spec: CnnSpec, params, image):
    """Detector forward: per-cell confidence map + max-cell class logits.

    Returns:
      conf_map: (GRID*GRID,) raw per-cell objectness logits.
      cls_logits: (N_CLASSES,) class logits of the most confident cell.
    """
    feat, it = _conv_stack(spec, params, image)
    w_head, b_head = next(it), next(it)
    out = feat @ w_head + b_head  # (GRID*GRID + N_CLASSES,)
    conf_map = out[: GRID * GRID]
    cls_logits = out[GRID * GRID :]
    return conf_map, cls_logits


def verify(spec: CnnSpec, params, image):
    """Verifier forward: refined confidence score + class logits."""
    feat, it = _conv_stack(spec, params, image)
    w_head, b_head = next(it), next(it)
    out = feat @ w_head + b_head  # (1 + N_CLASSES,)
    return out[:1], out[1:]


def build_detector(spec: CnnSpec) -> ModelDef:
    pb = make_params(spec, GRID * GRID + N_CLASSES)

    def apply(params, image):
        return detect(spec, params, image)

    return ModelDef(
        name=spec.name,
        kind="detector",
        params=pb.params,
        apply=apply,
        inputs=[IoSpec("image", (IMG, IMG, 3), "f32")],
        meta={"alias": spec.alias, "width": spec.width,
              "extra_convs": spec.extra_convs, "grid": GRID,
              "n_classes": N_CLASSES},
    )


def build_verifier(spec: CnnSpec) -> ModelDef:
    pb = make_params(spec, 1 + N_CLASSES)

    def apply(params, image):
        return verify(spec, params, image)

    return ModelDef(
        name=spec.name,
        kind="verifier",
        params=pb.params,
        apply=apply,
        inputs=[IoSpec("image", (IMG, IMG, 3), "f32")],
        meta={"alias": spec.alias, "width": spec.width,
              "extra_convs": spec.extra_convs, "n_classes": N_CLASSES},
    )
