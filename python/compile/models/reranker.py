"""Cross-encoder rerankers (the RAG workflow's reranker component).

Three sizes mirror the paper's MS-MARCO / BGE-base / BGE-v2 ladder.  Each
artifact scores up to ``RERANK_BATCH`` (query, document) pairs in one call:
pairs are packed as ``[query tokens ; doc tokens]`` sequences, encoded by a
non-causal transformer, mean-pooled and projected to a scalar relevance
score.  The batch dimension is folded into the attention head dimension
(per-head independence makes ``(B, H, S, dh) == (B*H, S, dh)``), so the
whole batch runs through the same Pallas kernels with no vmap.
"""

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from compile.common import IoSpec, ModelDef, ParamBuilder, largest_divisor_leq
from compile.kernels import mha_prefill, rmsnorm_matmul

VOCAB = 256
Q_LEN = 16
D_LEN = 32
PAIR_LEN = Q_LEN + D_LEN  # 48
RERANK_BATCH = 5  # pairs scored per artifact call; L3 loops ceil(k/5) batches


@dataclasses.dataclass(frozen=True)
class RerankerSpec:
    name: str
    alias: str
    d_model: int
    n_layers: int
    n_heads: int
    seed: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_mlp(self) -> int:
        return self.d_model * 4


RERANKERS: List[RerankerSpec] = [
    RerankerSpec("rr-48", "ms-marco-minilm", 48, 2, 2, 2001),
    RerankerSpec("rr-96", "bge-reranker-base", 96, 2, 3, 2002),
    RerankerSpec("rr-160", "bge-reranker-v2", 160, 3, 5, 2003),
]


def make_params(spec: RerankerSpec) -> ParamBuilder:
    pb = ParamBuilder(spec.seed)
    d = spec.d_model
    pb.gauss("embed", (VOCAB, d), 0.05)
    pb.gauss("pos_embed", (PAIR_LEN, d), 0.02)
    pb.gauss("seg_embed", (2, d), 0.02)  # query vs doc segment
    for i in range(spec.n_layers):
        pb.ones(f"l{i}.attn_gain", (d,))
        pb.dense(f"l{i}.wqkv", d, 3 * d)
        pb.dense(f"l{i}.wo", d, d)
        pb.ones(f"l{i}.mlp_gain", (d,))
        pb.dense(f"l{i}.w_up", d, spec.d_mlp)
        pb.dense(f"l{i}.w_down", spec.d_mlp, d)
    pb.ones("out_gain", (d,))
    pb.dense("w_score", d, 1)
    return pb


def _fused_norm_matmul(x, gain, w):
    # Single-grid-step tiling for the CPU artifact (see transformer.py).
    return rmsnorm_matmul(x, gain, w, row_block=x.shape[0], col_block=w.shape[1])


def score_pairs(spec: RerankerSpec, params, q_tokens, d_tokens):
    """Score RERANK_BATCH query/doc pairs.

    Args:
      q_tokens: (Q_LEN,) i32 query (shared across pairs).
      d_tokens: (RERANK_BATCH, D_LEN) i32 candidate documents.

    Returns:
      (RERANK_BATCH,) f32 relevance scores (harness ignores padded slots).
    """
    it = iter(params)
    embed, pos_embed, seg_embed = next(it), next(it), next(it)
    layers = [tuple(next(it) for _ in range(6)) for _ in range(spec.n_layers)]
    out_gain, w_score = next(it), next(it)

    b, s, d = RERANK_BATCH, PAIR_LEN, spec.d_model
    h, dh = spec.n_heads, spec.head_dim
    pair = jnp.concatenate(
        [jnp.broadcast_to(q_tokens, (b, Q_LEN)), d_tokens], axis=1
    )  # (b, s)
    seg = jnp.concatenate(
        [jnp.zeros((Q_LEN,), jnp.int32), jnp.ones((D_LEN,), jnp.int32)]
    )
    x = embed[pair] + pos_embed[None, :, :] + seg_embed[seg][None, :, :]

    for layer in layers:
        attn_gain, wqkv, wo, mlp_gain, w_up, w_down = layer
        qkv = _fused_norm_matmul(x.reshape(b * s, d), attn_gain, wqkv)
        qkv = qkv.reshape(b, s, 3 * d)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        # Fold batch into heads: (b, s, h, dh) -> (b*h, s, dh).
        fold = lambda t: t.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
        attn = mha_prefill(fold(q), fold(k), fold(v), causal=False, q_block=s, k_chunk=s)
        attn = attn.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + attn @ wo
        up = _fused_norm_matmul(x.reshape(b * s, d), mlp_gain, w_up)
        x = x + jax.nn.gelu(up.reshape(b, s, spec.d_mlp)) @ w_down

    pooled = x.mean(axis=1)  # (b, d)
    scores = _fused_norm_matmul(pooled, out_gain, w_score)[:, 0]
    return (scores,)


def build_reranker(spec: RerankerSpec) -> ModelDef:
    pb = make_params(spec)

    def apply(params, q_tokens, d_tokens):
        return score_pairs(spec, params, q_tokens, d_tokens)

    return ModelDef(
        name=spec.name,
        kind="reranker",
        params=pb.params,
        apply=apply,
        inputs=[
            IoSpec("q_tokens", (Q_LEN,), "i32"),
            IoSpec("d_tokens", (RERANK_BATCH, D_LEN), "i32"),
        ],
        meta={
            "alias": spec.alias,
            "d_model": spec.d_model,
            "n_layers": spec.n_layers,
            "n_heads": spec.n_heads,
            "batch": RERANK_BATCH,
            "q_len": Q_LEN,
            "d_len": D_LEN,
        },
    )
