"""Embedding retriever (the RAG workflow's retrieval component).

One artifact: score the query embedding against the whole corpus embedding
matrix (L1 Pallas kernel) and return the top ``K_MAX`` (scores, indices).
The corpus matrix is a runtime input — the Rust harness owns corpus
generation (it plants the ground-truth relevant document; DESIGN.md §2) —
and is uploaded to a device buffer once per corpus, not per request.
"""

from typing import Dict

import jax.numpy as jnp

from compile.common import IoSpec, ModelDef
from compile.kernels import retrieval_scores

CORPUS_N = 256  # documents
EMBED_D = 64  # embedding dimension
K_MAX = 50  # max retriever-k in the paper's space

RETRIEVER_SPEC: Dict = {
    "corpus_n": CORPUS_N,
    "embed_d": EMBED_D,
    "k_max": K_MAX,
}


def retrieve(corpus, query):
    """Top-K_MAX dot-product retrieval.

    Implemented with a full descending sort rather than ``lax.top_k``: the
    latter lowers to the ``topk`` HLO instruction, which the pinned
    xla_extension 0.5.1 text parser predates; ``sort`` round-trips cleanly.

    Returns:
      values: (K_MAX,) f32 similarity scores, descending.
      indices: (K_MAX,) i32 corpus row ids.
    """
    scores = retrieval_scores(corpus, query, n_block=64)
    order = jnp.argsort(-scores)[:K_MAX].astype(jnp.int32)
    return scores[order], order


def build_retriever() -> ModelDef:
    return ModelDef(
        name="retriever",
        kind="retriever",
        params=[],  # no weights: corpus + query are runtime inputs
        apply=lambda params, corpus, query: retrieve(corpus, query),
        inputs=[
            IoSpec("corpus", (CORPUS_N, EMBED_D), "f32"),
            IoSpec("query", (EMBED_D,), "f32"),
        ],
        meta=dict(RETRIEVER_SPEC),
    )
