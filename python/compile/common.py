"""Shared helpers for L2 model construction and AOT export.

Models are expressed as ``ModelDef``s: a deterministic parameter list
(numpy arrays derived from a per-model seed) plus an ``apply`` function
taking the parameters (as jnp arrays, in list order) followed by the data
inputs.  The AOT pass (:mod:`compile.aot`) lowers ``apply`` with the
parameters as *runtime inputs* — weights are shipped to the Rust side as a
raw little-endian binary blob and uploaded to device buffers once at
server start, keeping the HLO text small and the request path copy-free.
"""

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class ParamSpec:
    """One weight tensor: name, shape and byte offset into the weights bin."""

    name: str
    shape: Tuple[int, ...]

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclasses.dataclass
class IoSpec:
    """One data input / output of an artifact."""

    name: str
    shape: Tuple[int, ...]
    dtype: str  # "f32" | "i32"


@dataclasses.dataclass
class ModelDef:
    """A lowerable model: params + apply + data-input signature."""

    name: str
    kind: str  # generator | reranker | retriever | detector | verifier
    params: List[Tuple[str, np.ndarray]]
    apply: Callable  # apply(param_list, *data_inputs) -> tuple of outputs
    inputs: List[IoSpec]
    meta: Dict

    def param_specs(self) -> List[ParamSpec]:
        return [ParamSpec(n, tuple(a.shape)) for n, a in self.params]

    def flat_weights(self) -> np.ndarray:
        """All parameters concatenated as one f32 vector (bin file layout)."""
        return np.concatenate(
            [np.asarray(a, np.float32).reshape(-1) for _, a in self.params]
        )


class ParamBuilder:
    """Deterministic parameter factory (seeded, scaled gaussian init)."""

    def __init__(self, seed: int):
        self.rng = np.random.RandomState(seed)
        self.params: List[Tuple[str, np.ndarray]] = []

    def gauss(self, name: str, shape: Sequence[int], scale: float) -> np.ndarray:
        a = (self.rng.randn(*shape) * scale).astype(np.float32)
        self.params.append((name, a))
        return a

    def ones(self, name: str, shape: Sequence[int]) -> np.ndarray:
        a = np.ones(shape, np.float32)
        self.params.append((name, a))
        return a

    def dense(self, name: str, d_in: int, d_out: int) -> np.ndarray:
        """Variance-preserving dense init."""
        return self.gauss(name, (d_in, d_out), d_in**-0.5)


def largest_divisor_leq(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (>= 1)."""
    t = min(n, max(1, target))
    while n % t:
        t -= 1
    return t
