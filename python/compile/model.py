"""Model registry: every AOT-exportable artifact in one list.

``python -m compile.aot`` lowers each entry; the Rust runtime consumes the
resulting ``artifacts/manifest.json``.
"""

from typing import List

from compile.common import ModelDef
from compile.models import (
    DETECTORS,
    GENERATORS,
    RERANKERS,
    VERIFIERS,
    build_detector,
    build_generator,
    build_reranker,
    build_retriever,
    build_verifier,
)


def all_models() -> List[ModelDef]:
    """Every artifact, in manifest order."""
    models: List[ModelDef] = [build_retriever()]
    models += [build_reranker(s) for s in RERANKERS]
    models += [build_generator(s) for s in GENERATORS]
    models += [build_detector(s) for s in DETECTORS]
    models += [build_verifier(s) for s in VERIFIERS]
    return models
