"""AOT export: HLO text validity, manifest schema, weights-bin layout."""

import json

import numpy as np
import pytest

from compile.aot import lower_model, output_specs
from compile.model import all_models
from compile.models import build_retriever
from compile.models.detector import DETECTORS, build_detector
from compile.models.transformer import GENERATORS, build_generator


def test_registry_complete():
    models = all_models()
    names = [m.name for m in models]
    assert len(names) == len(set(names))
    kinds = {m.kind for m in models}
    assert kinds == {"retriever", "reranker", "generator", "detector", "verifier"}
    # 1 retriever + 3 rerankers + 6 generators + 3 detectors + 3 verifiers
    assert len(models) == 16


def test_retriever_hlo_text_parses():
    hlo = lower_model(build_retriever())
    assert hlo.startswith("HloModule")
    assert "ENTRY" in hlo
    # top-k emits a sort; the pallas scoring shows up as fusion/dot ops
    assert "sort" in hlo.lower()


def test_detector_hlo_has_convs():
    hlo = lower_model(build_detector(DETECTORS[0]))
    assert "convolution" in hlo


def test_output_specs_generator():
    outs = output_specs(build_generator(GENERATORS[0]))
    assert outs == [
        {"shape": [16], "dtype": "i32"},
        {"shape": [], "dtype": "f32"},
    ]


def test_flat_weights_layout_matches_param_specs():
    m = build_generator(GENERATORS[0])
    flat = m.flat_weights()
    offset = 0
    for name, arr in m.params:
        n = int(arr.size)
        np.testing.assert_array_equal(
            flat[offset : offset + n], np.asarray(arr, np.float32).reshape(-1)
        )
        offset += n
    assert offset == flat.size


def test_manifest_json_roundtrip(tmp_path):
    from compile.aot import export_model

    m = build_retriever()
    entry = export_model(m, tmp_path)
    blob = json.dumps({"artifacts": {m.name: entry}})
    parsed = json.loads(blob)
    e = parsed["artifacts"]["retriever"]
    assert e["kind"] == "retriever"
    assert (tmp_path / e["hlo"]).exists()
    assert e["inputs"][0]["name"] == "corpus"
    assert e["outputs"][0]["dtype"] == "f32"
    assert e["outputs"][1]["dtype"] == "i32"
