"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes, tile sizes and seeds; assert_allclose against
``compile.kernels.ref``.  This is the core correctness signal for the
compute that ends up inside every AOT artifact.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    mha_decode,
    mha_prefill,
    retrieval_scores,
    rmsnorm_matmul,
)
from compile.kernels import ref

TOL = dict(rtol=2e-4, atol=2e-4)


def _arr(rng, *shape):
    return jnp.asarray(rng.randn(*shape), jnp.float32)


# ---------------------------------------------------------------- prefill


@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([1, 2, 3, 5]),
    s_mult=st.sampled_from([1, 2, 3]),
    dh=st.sampled_from([16, 24, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_mha_prefill_matches_ref(h, s_mult, dh, causal, seed):
    s = 32 * s_mult
    rng = np.random.RandomState(seed % 100000)
    q, k, v = (_arr(rng, h, s, dh) for _ in range(3))
    out = mha_prefill(q, k, v, causal=causal)
    expect = ref.mha_prefill_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, expect, **TOL)


@pytest.mark.parametrize("q_block,k_chunk", [(8, 8), (16, 32), (32, 16), (64, 64)])
def test_mha_prefill_tile_invariance(q_block, k_chunk):
    """Output must not depend on the VMEM tiling schedule."""
    rng = np.random.RandomState(7)
    q, k, v = (_arr(rng, 2, 64, 32) for _ in range(3))
    base = ref.mha_prefill_ref(q, k, v, causal=True)
    out = mha_prefill(q, k, v, causal=True, q_block=q_block, k_chunk=k_chunk)
    np.testing.assert_allclose(out, base, **TOL)


def test_mha_prefill_causality():
    """Perturbing a future token must not change earlier outputs."""
    rng = np.random.RandomState(3)
    q, k, v = (_arr(rng, 2, 64, 32) for _ in range(3))
    out1 = np.asarray(mha_prefill(q, k, v, causal=True))
    k2 = k.at[:, -1, :].add(10.0)
    v2 = v.at[:, -1, :].add(10.0)
    out2 = np.asarray(mha_prefill(q, k2, v2, causal=True))
    np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], **TOL)
    assert not np.allclose(out1[:, -1], out2[:, -1])


def test_mha_prefill_rejects_bad_tiles():
    rng = np.random.RandomState(0)
    q, k, v = (_arr(rng, 1, 48, 16) for _ in range(3))
    with pytest.raises(ValueError):
        mha_prefill(q, k, v, q_block=32, k_chunk=32)  # 48 % 32 != 0


# ----------------------------------------------------------------- decode


@settings(max_examples=20, deadline=None)
@given(
    h=st.sampled_from([1, 2, 4]),
    smax_mult=st.sampled_from([1, 2, 3]),
    dh=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
    frac=st.floats(0.1, 1.0),
)
def test_mha_decode_matches_ref(h, smax_mult, dh, seed, frac):
    smax = 32 * smax_mult
    length = max(1, int(smax * frac))
    rng = np.random.RandomState(seed % 100000)
    q = _arr(rng, h, dh)
    kc, vc = _arr(rng, h, smax, dh), _arr(rng, h, smax, dh)
    out = mha_decode(q, kc, vc, length)
    expect = ref.mha_decode_ref(q, kc, vc, length)
    np.testing.assert_allclose(out, expect, **TOL)


def test_mha_decode_ignores_masked_tail():
    """Cache rows beyond ``length`` must not affect the output."""
    rng = np.random.RandomState(11)
    q = _arr(rng, 2, 32)
    kc, vc = _arr(rng, 2, 96, 32), _arr(rng, 2, 96, 32)
    out1 = np.asarray(mha_decode(q, kc, vc, 40))
    kc2 = kc.at[:, 40:, :].set(99.0)
    vc2 = vc.at[:, 40:, :].set(-99.0)
    out2 = np.asarray(mha_decode(q, kc2, vc2, 40))
    np.testing.assert_allclose(out1, out2, **TOL)


def test_mha_decode_equals_prefill_row():
    """Decode at position p must equal the prefill output row p."""
    rng = np.random.RandomState(13)
    h, s, dh = 2, 64, 32
    q, k, v = (_arr(rng, h, s, dh) for _ in range(3))
    full = np.asarray(ref.mha_prefill_ref(q, k, v, causal=True))
    p = 41
    out = np.asarray(mha_decode(q[:, p, :], k, v, p + 1))
    np.testing.assert_allclose(out, full[:, p, :], **TOL)


# -------------------------------------------------------- rmsnorm->matmul


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([1, 8, 33, 64]),
    d=st.sampled_from([48, 64, 96]),
    f=st.sampled_from([1, 64, 192]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsnorm_matmul_matches_ref(rows, d, f, seed):
    rng = np.random.RandomState(seed % 100000)
    x, g, w = _arr(rng, rows, d), _arr(rng, d), _arr(rng, d, f)
    rb = 1 if rows % 8 else 8
    fb = 1 if f % 32 else 32
    out = rmsnorm_matmul(x, g, w, row_block=rb, col_block=fb)
    np.testing.assert_allclose(out, ref.rmsnorm_matmul_ref(x, g, w), **TOL)


def test_rmsnorm_matmul_scale_invariance():
    """RMSNorm output is invariant to input scaling (up to eps)."""
    rng = np.random.RandomState(5)
    x, g, w = _arr(rng, 16, 64), _arr(rng, 64), _arr(rng, 64, 32)
    a = np.asarray(rmsnorm_matmul(x, g, w, row_block=16, col_block=32))
    b = np.asarray(rmsnorm_matmul(x * 3.7, g, w, row_block=16, col_block=32))
    np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_rmsnorm_matmul_shape_mismatch():
    rng = np.random.RandomState(0)
    with pytest.raises(ValueError):
        rmsnorm_matmul(_arr(rng, 8, 64), _arr(rng, 32), _arr(rng, 64, 16))


# -------------------------------------------------------------- retrieval


@settings(max_examples=20, deadline=None)
@given(
    n_mult=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_retrieval_scores_matches_ref(n_mult, d, seed):
    n = 64 * n_mult
    rng = np.random.RandomState(seed % 100000)
    c, q = _arr(rng, n, d), _arr(rng, d)
    out = retrieval_scores(c, q)
    np.testing.assert_allclose(out, ref.retrieval_scores_ref(c, q), **TOL)


def test_retrieval_top1_is_planted_doc():
    """A planted near-duplicate embedding must win the similarity race."""
    rng = np.random.RandomState(17)
    c = jnp.asarray(rng.randn(256, 64), jnp.float32)
    q = c[123] * 0.9 + 0.01 * jnp.asarray(rng.randn(64), jnp.float32)
    scores = np.asarray(retrieval_scores(c, q))
    assert scores.argmax() == 123
