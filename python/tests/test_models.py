"""L2 model correctness: shapes, determinism, decode/prefill consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models.detector import DETECTORS, VERIFIERS, GRID, N_CLASSES, detect, verify
from compile.models.detector import make_params as det_params
from compile.models.reranker import (
    D_LEN,
    Q_LEN,
    RERANK_BATCH,
    RERANKERS,
    score_pairs,
)
from compile.models.reranker import make_params as rr_params
from compile.models.transformer import (
    GEN_LEN,
    GENERATORS,
    SEQ,
    SMAX,
    VOCAB,
    decode_step,
    generate,
    make_params,
    prefill,
)

SMALL = GENERATORS[0]


def _params(spec):
    return [jnp.asarray(a) for _, a in make_params(spec).params]


def _toks(seed=0, n=SEQ):
    return jnp.asarray(np.random.RandomState(seed).randint(0, VOCAB, n), jnp.int32)


def test_prefill_shapes():
    params = _params(SMALL)
    logits, kc, vc = prefill(SMALL, params, _toks())
    assert logits.shape == (VOCAB,)
    assert kc.shape == (SMALL.n_layers, SMALL.n_heads, SMAX, 32)
    assert vc.shape == kc.shape
    # cache tail (rows >= SEQ) must be zero-padded
    assert np.abs(np.asarray(kc)[:, :, SEQ:, :]).max() == 0.0


def test_generate_shapes_and_determinism():
    params = _params(SMALL)
    f = jax.jit(lambda p, t: generate(SMALL, p, t))
    t1, s1 = f(params, _toks(1))
    t2, s2 = f(params, _toks(1))
    assert t1.shape == (GEN_LEN,) and t1.dtype == jnp.int32
    np.testing.assert_array_equal(t1, t2)
    assert float(s1) == float(s2)
    assert 0.0 <= float(s1) <= 1.0
    assert np.all((np.asarray(t1) >= 0) & (np.asarray(t1) < VOCAB))


def test_generate_depends_on_prompt():
    params = _params(SMALL)
    f = jax.jit(lambda p, t: generate(SMALL, p, t))
    t1, _ = f(params, _toks(1))
    t2, _ = f(params, _toks(2))
    assert not np.array_equal(np.asarray(t1), np.asarray(t2))


def test_decode_step_consistent_with_prefill():
    """Greedy step from prefill logits must match the scan's first token,
    and decode_step at SEQ must reproduce what a longer prefill computes."""
    params = _params(SMALL)
    toks = _toks(3)
    logits, kc, vc = jax.jit(lambda p, t: prefill(SMALL, p, t))(params, toks)
    tok0 = int(np.argmax(np.asarray(logits)))
    gen, _ = jax.jit(lambda p, t: generate(SMALL, p, t))(params, toks)
    assert int(np.asarray(gen)[0]) == tok0
    # one manual decode step == second generated token
    logits2, kc2, vc2 = jax.jit(
        lambda p, t, pos, kc, vc: decode_step(SMALL, p, t, pos, kc, vc)
    )(params, jnp.int32(tok0), jnp.int32(SEQ), kc, vc)
    assert int(np.argmax(np.asarray(logits2))) == int(np.asarray(gen)[1])


def test_generator_param_count_monotone():
    """The size ladder must be strictly increasing (latency proxy)."""
    counts = [
        sum(int(a.size) for _, a in make_params(s).params) for s in GENERATORS
    ]
    assert counts == sorted(counts)
    assert len(set(counts)) == len(counts)


def test_generator_weights_deterministic():
    a = make_params(SMALL).params
    b = make_params(SMALL).params
    for (na, wa), (nb, wb) in zip(a, b):
        assert na == nb
        np.testing.assert_array_equal(wa, wb)


# ---------------------------------------------------------------- reranker


@pytest.mark.parametrize("spec", RERANKERS, ids=lambda s: s.name)
def test_reranker_scores_shape(spec):
    params = [jnp.asarray(a) for _, a in rr_params(spec).params]
    q = _toks(5, Q_LEN)
    d = jnp.asarray(
        np.random.RandomState(6).randint(0, VOCAB, (RERANK_BATCH, D_LEN)), jnp.int32
    )
    (scores,) = score_pairs(spec, params, q, d)
    assert scores.shape == (RERANK_BATCH,)
    assert np.all(np.isfinite(np.asarray(scores)))


def test_reranker_scores_depend_on_doc():
    spec = RERANKERS[0]
    params = [jnp.asarray(a) for _, a in rr_params(spec).params]
    q = _toks(5, Q_LEN)
    rng = np.random.RandomState(6)
    d = jnp.asarray(rng.randint(0, VOCAB, (RERANK_BATCH, D_LEN)), jnp.int32)
    (s1,) = score_pairs(spec, params, q, d)
    d2 = d.at[2].set((d[2] + 37) % VOCAB)
    (s2,) = score_pairs(spec, params, q, d2)
    s1, s2 = np.asarray(s1), np.asarray(s2)
    assert s1[2] != s2[2]
    np.testing.assert_allclose(np.delete(s1, 2), np.delete(s2, 2), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- detector


@pytest.mark.parametrize("spec", DETECTORS, ids=lambda s: s.name)
def test_detector_shapes(spec):
    params = [jnp.asarray(a) for _, a in det_params(spec, GRID * GRID + N_CLASSES).params]
    img = jnp.asarray(np.random.RandomState(2).randn(32, 32, 3), jnp.float32)
    conf, cls = detect(spec, params, img)
    assert conf.shape == (GRID * GRID,)
    assert cls.shape == (N_CLASSES,)
    assert np.all(np.isfinite(np.asarray(conf)))


@pytest.mark.parametrize("spec", VERIFIERS, ids=lambda s: s.name)
def test_verifier_shapes(spec):
    params = [jnp.asarray(a) for _, a in det_params(spec, 1 + N_CLASSES).params]
    img = jnp.asarray(np.random.RandomState(2).randn(32, 32, 3), jnp.float32)
    score, cls = verify(spec, params, img)
    assert score.shape == (1,)
    assert cls.shape == (N_CLASSES,)


def test_cnn_cost_ladder_monotone():
    """Detector/verifier param counts must increase along the ladder."""
    det_counts = [
        sum(int(a.size) for _, a in det_params(s, GRID * GRID + N_CLASSES).params)
        for s in DETECTORS
    ]
    ver_counts = [
        sum(int(a.size) for _, a in det_params(s, 1 + N_CLASSES).params)
        for s in VERIFIERS
    ]
    assert det_counts == sorted(det_counts)
    assert ver_counts == sorted(ver_counts)
